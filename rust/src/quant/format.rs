//! The format-descriptor layer: every group-quantized FP format in the
//! crate is described by a [`GroupFormat`] — group size, element codec,
//! scale codec, and whether a second (per-tensor) scaling level applies —
//! and quantize/decode/packed-GEMM are parameterized by the descriptor
//! instead of the historical implicit `MX_GROUP = 32` global.
//!
//! Three formats ship as consts:
//!
//! * [`MXFP4`] — the paper's format: E2M1 nibbles, E8M0 power-of-two
//!   scales, 32-element groups. `quant::mxfp4::MX_GROUP` is now *derived*
//!   from this descriptor, so the legacy fast paths and the descriptor
//!   path can never disagree about geometry.
//! * [`NVFP4`] — 16-element groups with E4M3-encoded fractional scales and
//!   two-level scaling (a per-tensor power-of-two scale keeps the E4M3
//!   group scales in range), after "Pretraining Large Language Models with
//!   NVFP4".
//! * [`MXFP8`] — E4M3 elements with E8M0 scales over 32-groups; the byte
//!   twin of `quant::fp8::mxfp8_rtn`.
//!
//! The reference implementations here ([`quantize_ref`], [`decode_ref`],
//! [`gemm_ref`]) are scalar and deliberately simple; `kernels::Backend`
//! exposes them as `quantize_group`/`decode_group`/`gemm_group` trait
//! *defaults*, so every backend (scalar, parallel, simd, parallel+simd) is
//! bit-identical on the descriptor path by construction. A backend that
//! overrides those hooks takes on the burden of preserving bit-identity —
//! `tests/backend_equivalence.rs` pins it for all formats × backends.
//!
//! This module also owns [`Method`], the single method-axis enum shared by
//! training (`train::TrainMethod`) and serving (`serve::cache::ServeMethod`)
//! — those names are now thin type aliases. One `name()`/`parse()` registry
//! feeds CLI flags, bench args, RunRecords and ServeRecords, so adding a
//! recipe is a one-file change.

use crate::quant::e2m1::{e2m1_decode, e2m1_encode_rtn, e2m1_encode_sr, E2M1_MAX};
use crate::quant::e8m0::E8m0;
use crate::quant::fp8::{e4m3_ceil, e4m3_decode_bits, e4m3_encode_bits, E4M3_MAX};
use crate::quant::mxfp4::QuantMode;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// How the in-group elements are stored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemCodec {
    /// 4-bit E2M1 (sign + 2-bit exponent + 1-bit mantissa), packed two
    /// codes per byte, low nibble = even column. Grid max 6.
    E2m1,
    /// 8-bit E4M3 (sign + 4-bit exponent + 3-bit mantissa), one byte per
    /// element. Grid max 448.
    E4m3,
}

impl ElemCodec {
    pub const fn bits(self) -> usize {
        match self {
            ElemCodec::E2m1 => 4,
            ElemCodec::E4m3 => 8,
        }
    }

    /// Largest representable magnitude on the element grid.
    pub const fn max(self) -> f32 {
        match self {
            ElemCodec::E2m1 => E2M1_MAX,
            ElemCodec::E4m3 => E4M3_MAX,
        }
    }
}

/// How the per-group scale byte is encoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleCodec {
    /// Biased power-of-two exponent (the MX scale). Ceil-rounded via
    /// `E8m0::from_absmax`, so `amax / scale <= elem_max` always.
    E8m0,
    /// E4M3 fractional scale (NVFP4). Ceil-rounded via `e4m3_ceil` with a
    /// floor at the smallest E4M3 subnormal, preserving the same coverage
    /// guarantee: the group amax never exceeds `elem_max * scale` after
    /// the two-level tensor scale is applied.
    E4m3,
}

/// A group-quantized FP format descriptor. Const-constructible so group
/// sizes remain usable in array-length position (`[0.0; MXFP4.group]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupFormat {
    /// Registry name (also the RunRecord/CLI spelling for pure formats).
    pub name: &'static str,
    /// Elements per scale group. Rows must be a multiple of this.
    pub group: usize,
    /// Element storage codec.
    pub elem: ElemCodec,
    /// Scale storage codec.
    pub scale: ScaleCodec,
    /// Two-level scaling: a single per-tensor power-of-two scale chosen so
    /// every per-group scale fits the scale codec's range.
    pub two_level: bool,
    /// Default forward-pass rounding mode. Backward passes typically
    /// override with a stochastic mode at the call site.
    pub rounding: QuantMode,
}

/// The paper's MXFP4: 32-element groups, E2M1 elements, E8M0 scales.
pub const MXFP4: GroupFormat = GroupFormat {
    name: "mxfp4",
    group: 32,
    elem: ElemCodec::E2m1,
    scale: ScaleCodec::E8m0,
    two_level: false,
    rounding: QuantMode::Rtn,
};

/// NVFP4: 16-element groups, E2M1 elements, E4M3 scales, two-level.
pub const NVFP4: GroupFormat = GroupFormat {
    name: "nvfp4",
    group: 16,
    elem: ElemCodec::E2m1,
    scale: ScaleCodec::E4m3,
    two_level: true,
    rounding: QuantMode::Rtn,
};

/// MXFP8: 32-element groups, E4M3 elements, E8M0 scales — the byte-level
/// twin of the `fp8::mxfp8_rtn` quant-dequant baseline.
pub const MXFP8: GroupFormat = GroupFormat {
    name: "mxfp8",
    group: 32,
    elem: ElemCodec::E4m3,
    scale: ScaleCodec::E8m0,
    two_level: false,
    rounding: QuantMode::Rtn,
};

/// All descriptor-backed formats, for registry-style lookups.
pub const FORMATS: [&GroupFormat; 3] = [&MXFP4, &NVFP4, &MXFP8];

/// Look a format up by its registry name.
pub fn format_by_name(name: &str) -> Option<&'static GroupFormat> {
    FORMATS.iter().copied().find(|f| f.name == name)
}

/// Smallest positive E4M3 value (subnormal step 2^-9) — the floor for
/// E4M3-encoded group scales so a zero group still has an invertible scale.
pub const E4M3_MIN_POS: f32 = 1.0 / 512.0;

impl GroupFormat {
    pub const fn groups_per_row(&self, cols: usize) -> usize {
        cols / self.group
    }

    /// Packed element bytes for a `rows x cols` tensor.
    pub const fn code_bytes(&self, rows: usize, cols: usize) -> usize {
        rows * cols * self.elem.bits() / 8
    }

    /// The per-tensor (second-level) scale: the smallest power of two
    /// `s_t` such that every group scale `amax_g / (s_t * elem_max)` fits
    /// the scale codec's range. Power-of-two by choice (not in the NVFP4
    /// spec, which allows f32) so that dividing by it is exact and the
    /// bit-identity contract is trivial to uphold; reuses E8M0's ceil
    /// discipline with target `scale_max * elem_max`.
    pub fn tensor_scale(&self, global_absmax: f32) -> f32 {
        if !self.two_level {
            return 1.0;
        }
        E8m0::from_absmax(global_absmax, E4M3_MAX * self.elem.max()).value()
    }

    /// Encode one group scale from the group absmax (already divided by the
    /// tensor scale for two-level formats). Returns (byte, decoded value);
    /// the decoded value is exactly what `decode_scale(byte)` yields.
    pub fn encode_scale(&self, group_absmax: f32, tensor_scale: f32) -> (u8, f32) {
        match self.scale {
            ScaleCodec::E8m0 => {
                let s = E8m0::from_absmax(group_absmax, self.elem.max());
                (s.0, s.value())
            }
            ScaleCodec::E4m3 => {
                let target = group_absmax / (tensor_scale * self.elem.max());
                let s = e4m3_ceil(target).max(E4M3_MIN_POS);
                (e4m3_encode_bits(s), s)
            }
        }
    }

    /// Decode one group-scale byte (tensor scale NOT included).
    pub fn decode_scale(&self, byte: u8) -> f32 {
        match self.scale {
            ScaleCodec::E8m0 => E8m0(byte).value(),
            ScaleCodec::E4m3 => e4m3_decode_bits(byte),
        }
    }
}

/// A group-quantized tensor in genuine storage layout: packed element
/// codes (nibbles for E2M1, low nibble = even column; bytes for E4M3),
/// one raw scale byte per group, plus the two-level tensor scale.
#[derive(Clone, Debug)]
pub struct GroupTensor {
    pub fmt: &'static GroupFormat,
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<u8>,
    pub scales: Vec<u8>,
    /// 1.0 for single-level formats.
    pub tensor_scale: f32,
}

impl GroupTensor {
    pub fn groups_per_row(&self) -> usize {
        self.fmt.groups_per_row(self.cols)
    }

    /// Bytes actually stored: packed codes + scale bytes (+ 4 for the
    /// tensor scale when two-level).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + if self.fmt.two_level { 4 } else { 0 }
    }

    /// Decoded scale of group `g` in row `r`, tensor scale included.
    pub fn scale_at(&self, r: usize, g: usize) -> f32 {
        self.fmt.decode_scale(self.scales[r * self.groups_per_row() + g]) * self.tensor_scale
    }

    /// Decode element `(r, c)` on the element grid (scales not applied).
    fn raw_elem(&self, r: usize, c: usize) -> f32 {
        let flat = r * self.cols + c;
        match self.fmt.elem {
            ElemCodec::E2m1 => {
                let byte = self.codes[flat >> 1];
                let code = (byte >> ((flat & 1) * 4)) & 0x0F;
                e2m1_decode(code)
            }
            ElemCodec::E4m3 => e4m3_decode_bits(self.codes[flat]),
        }
    }

    /// Decode the full tensor to dense f32.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        self.decode_rows_into(0, self.rows, &mut out);
        out
    }

    /// Decode rows `[row0, row0+n)` into `out` (length `n * cols`).
    pub fn decode_rows_into(&self, row0: usize, n: usize, out: &mut [f32]) {
        assert_eq!(out.len(), n * self.cols);
        let g = self.fmt.group;
        for r in 0..n {
            for gi in 0..self.groups_per_row() {
                let s = self.scale_at(row0 + r, gi);
                for i in 0..g {
                    let c = gi * g + i;
                    out[r * self.cols + c] = self.raw_elem(row0 + r, c) * s;
                }
            }
        }
    }
}

fn absmax(data: &[f32]) -> f32 {
    data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Scalar reference quantizer for any [`GroupFormat`]. QuEST rounding is
/// *not* on the descriptor path (its clip search and trust mask are
/// MXFP4-specific and stay on `Mxfp4Tensor::quantize`).
///
/// SR element streams are consumed in flat row-major element order, one
/// uniform draw per element — the same discipline the legacy MXFP4 path
/// uses, so thread count and lane width can never reorder draws.
pub fn quantize_ref(
    data: &[f32],
    rows: usize,
    cols: usize,
    fmt: &'static GroupFormat,
    mode: QuantMode,
    rng: &mut Rng,
) -> GroupTensor {
    assert_eq!(data.len(), rows * cols);
    assert_eq!(cols % fmt.group, 0, "cols {cols} not divisible by group {}", fmt.group);
    assert!(
        mode != QuantMode::Quest,
        "QuEST rounding stays on the dedicated MXFP4 path (Mxfp4Tensor::quantize)"
    );
    let g = fmt.group;
    let gpr = fmt.groups_per_row(cols);
    let tensor_scale = fmt.tensor_scale(absmax(data));
    let mut codes = vec![0u8; fmt.code_bytes(rows, cols)];
    let mut scales = vec![0u8; rows * gpr];
    let elem_max = fmt.elem.max();
    for r in 0..rows {
        for gi in 0..gpr {
            let group = &data[r * cols + gi * g..r * cols + gi * g + g];
            let (sbyte, sval) = fmt.encode_scale(absmax(group), tensor_scale);
            scales[r * gpr + gi] = sbyte;
            let inv = 1.0 / (sval * tensor_scale);
            for (i, &x) in group.iter().enumerate() {
                let xs = x * inv;
                let flat = r * cols + gi * g + i;
                match fmt.elem {
                    ElemCodec::E2m1 => {
                        let code = match mode {
                            QuantMode::Rtn | QuantMode::Quest => e2m1_encode_rtn(xs),
                            // the 3/4 prescale makes SR exactly unbiased on
                            // the E2M1 grid (|0.75 x| <= 4.5 < 6 under the
                            // ceil-rounded scale); callers undo it with a
                            // 4/3 post-scale
                            QuantMode::SrPrescaled => {
                                e2m1_encode_sr(0.75 * xs, rng.uniform_f32())
                            }
                            QuantMode::Sr => {
                                e2m1_encode_sr(xs.clamp(-E2M1_MAX, E2M1_MAX), rng.uniform_f32())
                            }
                        };
                        codes[flat >> 1] |= code << ((flat & 1) * 4);
                    }
                    ElemCodec::E4m3 => {
                        assert!(
                            mode == QuantMode::Rtn,
                            "stochastic rounding is not implemented for E4M3 elements"
                        );
                        let _ = elem_max;
                        codes[flat] = e4m3_encode_bits(xs);
                    }
                }
            }
        }
    }
    GroupTensor { fmt, rows, cols, codes, scales, tensor_scale }
}

/// Scalar reference decode (mirrors `GroupTensor::dequantize`).
pub fn decode_ref(t: &GroupTensor) -> Vec<f32> {
    t.dequantize()
}

/// Scalar reference packed GEMM: `a` is `m x k`, `b` is `n x k` (both
/// packed), output is `m x n` with `out[i][j] = dot(a_i, b_j)` — the same
/// convention as `Backend::gemm_mxfp4`.
pub fn gemm_ref(a: &GroupTensor, b: &GroupTensor) -> Vec<f32> {
    assert_eq!(a.cols, b.cols);
    let b_dec = b.dequantize();
    gemm_predec_ref(a, &b_dec, b.rows)
}

/// Decode-once variant: `b_dec` is the pre-decoded `n x k` right operand.
pub fn gemm_predec_ref(a: &GroupTensor, b_dec: &[f32], n: usize) -> Vec<f32> {
    let (m, k) = (a.rows, a.cols);
    assert_eq!(b_dec.len(), n * k);
    let a_dec = a.dequantize();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let ar = &a_dec[i * k..(i + 1) * k];
        for j in 0..n {
            out[i * n + j] = crate::kernels::scalar::dot_f32(ar, &b_dec[j * k..(j + 1) * k]);
        }
    }
    out
}

/// The single method-axis enum shared by training and serving. The spelled
/// names (`name()`) are the wire format: CLI flags, bench args, RunRecord
/// and ServeRecord JSON all go through this registry, so adding a recipe
/// means adding a variant here and a forward/backward arm in
/// `train::layer` — nothing else.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Dense f32 everywhere — the accuracy ceiling.
    F32,
    /// MXFP8 quant-dequant on forward operands; backward in f32.
    Mxfp8,
    /// The paper's recipe: QuEST-rounded MXFP4 forward with randomized
    /// Hadamard + trust masks, stochastic MXFP4 backward.
    Quartet,
    /// Naive round-to-nearest MXFP4 — the "what you lose without the
    /// recipe" baseline.
    Rtn,
    /// NVFP4 (16-element groups, E4M3 scales, two-level): RTN forward on
    /// the descriptor path, randomized group-16 Hadamard + SR backward.
    Nvfp4,
    /// The differentiable-gradient-estimator + outlier clamp-and-compensate
    /// recipe ("Optimizing LLM Training Using FP4 Quantization"): MXFP4
    /// RTN forward with activation outliers clamped at a quantile and
    /// compensated through a sparse f32 GEMM; f32 backward with a capped
    /// power-surrogate derivative on the weight gradient.
    Fp4Clamp,
}

impl Method {
    /// Every method on the axis, in record/report order.
    pub const ALL: [Method; 6] = [
        Method::F32,
        Method::Mxfp8,
        Method::Quartet,
        Method::Rtn,
        Method::Nvfp4,
        Method::Fp4Clamp,
    ];

    /// The original four-method axis (paper Table 3 core). Fixed-width
    /// consumers (the ordering asserts in `tests/native_training.rs`)
    /// iterate this, not [`Method::ALL`], so the axis can keep growing.
    pub const CORE: [Method; 4] = [Method::F32, Method::Mxfp8, Method::Quartet, Method::Rtn];

    /// The registry/wire spelling.
    pub fn name(self) -> &'static str {
        match self {
            Method::F32 => "f32",
            Method::Mxfp8 => "mxfp8",
            Method::Quartet => "quartet",
            Method::Rtn => "rtn",
            Method::Nvfp4 => "nvfp4",
            Method::Fp4Clamp => "fp4-clamp",
        }
    }

    /// Parse a registry spelling ("fp4_clamp" is accepted as an alias for
    /// "fp4-clamp"; "bf16" is deliberately *not* a method — records use it
    /// only as the paper-data baseline label).
    pub fn parse(s: &str) -> Result<Method> {
        let canon = s.replace('_', "-");
        for m in Method::ALL {
            if m.name() == canon {
                return Ok(m);
            }
        }
        bail!("unknown method {s:?} (expected {})", Method::axis_help())
    }

    /// "f32|mxfp8|quartet|rtn|..." — for CLI help strings.
    pub fn axis_help() -> String {
        Method::ALL.map(|m| m.name()).join("|")
    }

    /// The group format backing this method's forward GEMM operands, if
    /// it quantizes them.
    pub fn format(self) -> Option<&'static GroupFormat> {
        match self {
            Method::F32 => None,
            Method::Mxfp8 => Some(&MXFP8),
            Method::Quartet | Method::Rtn | Method::Fp4Clamp => Some(&MXFP4),
            Method::Nvfp4 => Some(&NVFP4),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fp8::{e4m3, mxfp8_rtn};
    use crate::quant::mxfp4::Mxfp4Tensor;

    #[test]
    fn mxfp4_descriptor_path_is_bit_identical_to_legacy() {
        let mut rng = Rng::new(11);
        let x = rng.gaussian_vec(8 * 128, 1.3);
        for mode in [QuantMode::Rtn, QuantMode::SrPrescaled, QuantMode::Sr] {
            let mut r1 = Rng::new(99);
            let mut r2 = Rng::new(99);
            let legacy = Mxfp4Tensor::quantize(&x, 8, 128, mode, &mut r1);
            let via_fmt = quantize_ref(&x, 8, 128, &MXFP4, mode, &mut r2);
            assert_eq!(legacy.codes, via_fmt.codes, "{mode:?} codes");
            assert_eq!(
                legacy.scales.iter().map(|s| s.0).collect::<Vec<_>>(),
                via_fmt.scales,
                "{mode:?} scales"
            );
            assert_eq!(via_fmt.tensor_scale, 1.0);
            assert_eq!(legacy.dequantize(), via_fmt.dequantize(), "{mode:?} dequant");
        }
    }

    #[test]
    fn mxfp8_descriptor_path_matches_qdq_reference() {
        let mut rng = Rng::new(5);
        let x = rng.gaussian_vec(4 * 96, 2.0);
        let mut r = Rng::new(0);
        let t = quantize_ref(&x, 4, 96, &MXFP8, QuantMode::Rtn, &mut r);
        assert_eq!(t.dequantize(), mxfp8_rtn(&x));
        assert_eq!(t.storage_bytes(), 4 * 96 + 4 * 3);
    }

    #[test]
    fn nvfp4_groups_are_covered_by_their_scales() {
        let mut rng = Rng::new(7);
        for amp in [1e-5f32, 1.0, 3000.0, 1e6] {
            let x = rng.gaussian_vec(6 * 48, amp);
            let mut r = Rng::new(1);
            let t = quantize_ref(&x, 6, 48, &NVFP4, QuantMode::Rtn, &mut r);
            assert_eq!(t.groups_per_row(), 3);
            for row in 0..6 {
                for g in 0..3 {
                    let grp = &x[row * 48 + g * 16..row * 48 + g * 16 + 16];
                    let amax = grp.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let s = t.scale_at(row, g);
                    assert!(
                        amax <= E2M1_MAX * s * (1.0 + 1e-6),
                        "amp {amp}: group amax {amax} exceeds 6*scale {s}"
                    );
                }
            }
            let dq = t.dequantize();
            assert!(dq.iter().all(|v| v.is_finite()));
            // reconstruction is sane: correlation with the input is high
            let err = crate::util::stats::mse(&dq, &x);
            let var = crate::util::stats::mse(&x, &vec![0.0; x.len()]);
            assert!(err < 0.1 * var, "amp {amp}: mse {err} vs var {var}");
        }
    }

    #[test]
    fn nvfp4_two_level_scale_extends_e4m3_range() {
        // group scales alone top out at 448; a tensor with amax ~ 1e6
        // needs the per-tensor level to stay covered
        let mut rng = Rng::new(3);
        let mut x = rng.gaussian_vec(2 * 32, 1.0);
        x[5] = 9.0e5;
        let mut r = Rng::new(1);
        let t = quantize_ref(&x, 2, 32, &NVFP4, QuantMode::Rtn, &mut r);
        assert!(t.tensor_scale > 1.0, "tensor scale {}", t.tensor_scale);
        let dq = t.dequantize();
        assert!((dq[5] - 9.0e5).abs() / 9.0e5 < 0.25);
    }

    #[test]
    fn nvfp4_scale_bytes_roundtrip_exactly() {
        // e4m3_ceil lands on the E4M3 grid, so encode(decode(byte)) is
        // lossless and scale_at returns exactly what was stored
        let mut rng = Rng::new(21);
        let x = rng.gaussian_vec(4 * 64, 5.0);
        let mut r = Rng::new(1);
        let t = quantize_ref(&x, 4, 64, &NVFP4, QuantMode::Rtn, &mut r);
        for &b in &t.scales {
            let v = NVFP4.decode_scale(b);
            assert_eq!(e4m3_encode_bits(v), b);
            assert_eq!(e4m3(v), v, "scale {v} not on the E4M3 grid");
        }
    }

    #[test]
    fn gemm_ref_matches_dense_reference() {
        let mut rng = Rng::new(13);
        let a = rng.gaussian_vec(5 * 32, 1.0);
        let b = rng.gaussian_vec(7 * 32, 1.0);
        for fmt in FORMATS {
            let mut r = Rng::new(1);
            let at = quantize_ref(&a, 5, 32, fmt, QuantMode::Rtn, &mut r);
            let bt = quantize_ref(&b, 7, 32, fmt, QuantMode::Rtn, &mut r);
            let y = gemm_ref(&at, &bt);
            let (ad, bd) = (at.dequantize(), bt.dequantize());
            for i in 0..5 {
                for j in 0..7 {
                    let want: f32 =
                        (0..32).map(|k| ad[i * 32 + k] * bd[j * 32 + k]).sum();
                    assert!((y[i * 7 + j] - want).abs() < 1e-4 * want.abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn method_registry_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert_eq!(Method::parse("fp4_clamp").unwrap(), Method::Fp4Clamp);
        assert!(Method::parse("bf16").is_err());
        assert!(Method::parse("fp4").is_err());
        assert_eq!(&Method::CORE[..], &Method::ALL[..4]);
        assert!(Method::axis_help().contains("nvfp4"));
    }

    #[test]
    fn format_registry_lookup() {
        assert_eq!(format_by_name("nvfp4").unwrap().group, 16);
        assert_eq!(format_by_name("mxfp4").unwrap().group, 32);
        assert!(format_by_name("int4").is_none());
        assert_eq!(Method::Nvfp4.format().unwrap().scale, ScaleCodec::E4m3);
        assert_eq!(Method::F32.format(), None);
    }

    #[test]
    fn storage_accounting_includes_two_level_scale() {
        let mut rng = Rng::new(2);
        let x = rng.gaussian_vec(4 * 32, 1.0);
        let mut r = Rng::new(1);
        let t4 = quantize_ref(&x, 4, 32, &NVFP4, QuantMode::Rtn, &mut r);
        // 4*32 nibbles = 64 bytes, 4*2 scale bytes, +4 tensor scale
        assert_eq!(t4.storage_bytes(), 64 + 8 + 4);
        let m4 = quantize_ref(&x, 4, 32, &MXFP4, QuantMode::Rtn, &mut r);
        assert_eq!(m4.storage_bytes(), 64 + 4);
    }
}
