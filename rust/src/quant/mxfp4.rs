//! Packed MXFP4 tensors: E2M1 nibbles (2 per byte) + one E8M0 scale byte
//! per 32-element group (the storage format Blackwell's `tcgen05.mma`
//! block-scaled matmul consumes — Fig 3 / Fig 5).
//!
//! The hot loops that produce and consume these tensors live in
//! [`crate::kernels`] behind the `Backend` trait; the `quantize` /
//! `mxfp4_gemm` / `f32_gemm` entry points below are kept as thin
//! forwarding shims for API stability and route through the selected
//! backend (`kernels::active()` — scalar unless `QUARTET_BACKEND` /
//! `--backend` says otherwise).

use crate::quant::e2m1::{e2m1_decode, E2M1_MAX};
use crate::quant::e8m0::E8m0;
use crate::util::rng::Rng;

/// MX group size (OCP spec: 1-D blocks of 32) — derived from the
/// [`crate::quant::format::MXFP4`] descriptor so the legacy fast paths and
/// the descriptor-parameterized paths share one source of truth.
pub const MX_GROUP: usize = super::format::MXFP4.group;

/// QuEST RMSE-optimal clip multiplier for E2M1 on unit-Gaussian groups —
/// pinned to the value fitted in `python/compile/formats.py`.
pub const QUEST_ALPHA_E2M1: f32 = 2.925;

/// How element codes are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// AbsMax group scale, round-to-nearest.
    Rtn,
    /// AbsMax group scale, stochastic rounding of (3/4)·x (Algorithm 1
    /// backward; dequantized values include the 3/4 shrinkage).
    SrPrescaled,
    /// AbsMax group scale, plain stochastic rounding (no prescale).
    Sr,
    /// QuEST: RMSE-optimal clip snapped to the better of the two
    /// neighbouring E8M0 binades + trust mask.
    Quest,
}

/// A 2-D row-major MXFP4 tensor: `rows x cols` with cols % 32 == 0.
#[derive(Debug, Clone)]
pub struct Mxfp4Tensor {
    pub rows: usize,
    pub cols: usize,
    /// packed element codes, low nibble = even column; rows*cols/2 bytes
    pub codes: Vec<u8>,
    /// per-group scales, rows * cols/32 entries, row-major
    pub scales: Vec<E8m0>,
    /// QuEST trust mask (bit per element, row-major), only for Quest mode
    pub mask: Option<Vec<u64>>,
}

impl Mxfp4Tensor {
    pub fn groups_per_row(&self) -> usize {
        self.cols / MX_GROUP
    }

    /// Bytes of real storage (what HBM traffic would be on Blackwell):
    /// packed nibbles + one scale byte per group, plus — for Quest-mode
    /// tensors — the trust mask the backward pass reads, counted at its
    /// exact payload of one bit per element (the in-memory u64 packing's
    /// tail padding is not traffic, so bits/value stays shape-independent).
    /// Omitting the mask understated the Fig 5 traffic for QuEST tensors
    /// by a full bit per value.
    pub fn storage_bytes(&self) -> usize {
        let mask_bytes = if self.mask.is_some() {
            (self.rows * self.cols + 7) / 8
        } else {
            0
        };
        self.codes.len() + self.scales.len() + mask_bytes
    }

    /// Quantize a dense f32 tensor through the active
    /// [`crate::kernels::Backend`].
    pub fn quantize(data: &[f32], rows: usize, cols: usize, mode: QuantMode,
                    rng: &mut Rng) -> Mxfp4Tensor {
        crate::kernels::active().quantize_mxfp4(data, rows, cols, mode, rng)
    }

    /// Dequantize back to dense f32 (exactly the values a tensor core
    /// would consume: code value × group scale).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let gpr = self.groups_per_row();
        for r in 0..self.rows {
            for g in 0..gpr {
                let s = self.scales[r * gpr + g].value();
                let base = r * self.cols + g * MX_GROUP;
                for i in 0..MX_GROUP {
                    let flat = base + i;
                    let byte = self.codes[flat / 2];
                    let code = if flat & 1 == 0 { byte & 0xf } else { byte >> 4 };
                    out[flat] = e2m1_decode(code) * s;
                }
            }
        }
        out
    }

    /// Trust-mask lookup (Quest mode); true = gradient passes.
    pub fn mask_at(&self, flat: usize) -> bool {
        match &self.mask {
            Some(m) => m[flat / 64] & (1u64 << (flat % 64)) != 0,
            None => true,
        }
    }
}

/// QuEST scale selection: clip = α·rms; evaluate both neighbouring E8M0
/// binades against the group and keep the lower-MSE one. Returns the
/// scale and the clip threshold (for the trust mask). Shared by every
/// backend so the QuEST numerics are written exactly once.
pub(crate) fn quest_scale(group: &[f32]) -> (E8m0, Option<f32>) {
    let rms = (group.iter().map(|&v| v * v).sum::<f32>() / group.len() as f32
        + 1e-20)
        .sqrt();
    let clip = QUEST_ALPHA_E2M1 * rms;
    let e = (clip / E2M1_MAX)
        .max((crate::quant::e8m0::MIN_EXP as f32).exp2())
        .log2();
    let lo = E8m0::from_exp(e.floor() as i32);
    let hi = E8m0::from_exp(e.ceil() as i32);
    let mse = |s: E8m0| -> f64 {
        let inv = 1.0 / s.value();
        group
            .iter()
            .map(|&v| {
                let q = crate::quant::e2m1::e2m1_rtn(v * inv) * s.value();
                ((q - v) as f64).powi(2)
            })
            .sum::<f64>()
    };
    let s = if mse(lo) <= mse(hi) { lo } else { hi };
    (s, Some(s.value() * E2M1_MAX))
}

// ---------------------------------------------------------------------------
// packed block-scaled GEMM — the tcgen05.mma stand-in
// ---------------------------------------------------------------------------

/// C = A · Bᵀ over packed MXFP4 operands, f32 accumulation.
///
/// A: [M, K], B: [N, K], both with per-32-group scales along K — exactly
/// the layout `tcgen05.mma` block-scaled GEMM expects. Forwards to the
/// active [`crate::kernels::Backend`]; the scalar reference decodes two
/// elements per byte via a 256-entry LUT, accumulates a per-group dot
/// product in f32 and applies `sa·sb` once per group (the hardware
/// applies scales along K the same way).
pub fn mxfp4_gemm(a: &Mxfp4Tensor, b: &Mxfp4Tensor) -> Vec<f32> {
    crate::kernels::active().gemm_mxfp4(a, b)
}

/// Dense f32 GEMM C = A·Bᵀ (baseline for the kernel benches), routed
/// through the active [`crate::kernels::Backend`].
pub fn f32_gemm(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    crate::kernels::active().gemm_f32(a, b, m, n, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        rng.gaussian_vec(rows * cols, 1.0)
    }

    #[test]
    fn quantize_dequantize_on_grid() {
        let mut rng = Rng::new(1);
        let x = rand_mat(&mut rng, 4, 64);
        let t = Mxfp4Tensor::quantize(&x, 4, 64, QuantMode::Rtn, &mut rng);
        let dq = t.dequantize();
        let gpr = 2;
        for r in 0..4 {
            for g in 0..gpr {
                let s = t.scales[r * gpr + g].value();
                for i in 0..MX_GROUP {
                    let v = dq[r * 64 + g * MX_GROUP + i] / s;
                    assert!(
                        crate::quant::e2m1::E2M1_GRID
                            .iter()
                            .any(|&gv| (gv - v.abs()).abs() < 1e-6),
                        "{v}"
                    );
                }
            }
        }
    }

    #[test]
    fn rtn_error_bounded_by_half_step() {
        let mut rng = Rng::new(2);
        let x = rand_mat(&mut rng, 8, 128);
        let t = Mxfp4Tensor::quantize(&x, 8, 128, QuantMode::Rtn, &mut rng);
        let dq = t.dequantize();
        let gpr = 4;
        for r in 0..8 {
            for g in 0..gpr {
                let s = t.scales[r * gpr + g].value();
                for i in 0..MX_GROUP {
                    let idx = r * 128 + g * MX_GROUP + i;
                    assert!((dq[idx] - x[idx]).abs() <= s + 1e-6);
                }
            }
        }
    }

    #[test]
    fn storage_is_4_25_bits_per_value() {
        let mut rng = Rng::new(3);
        let x = rand_mat(&mut rng, 32, 512);
        let t = Mxfp4Tensor::quantize(&x, 32, 512, QuantMode::Rtn, &mut rng);
        let bits = t.storage_bytes() as f64 * 8.0 / (32.0 * 512.0);
        assert!((bits - 4.25).abs() < 1e-9, "{bits}"); // 4 + 8/32
    }

    #[test]
    fn quest_storage_includes_trust_mask_bit() {
        // the maskless formats stay at 4 + 8/32 = 4.25 bits/value; the
        // QuEST trust mask (bit per element) adds exactly one more bit —
        // the storage split the Fig 5 traffic accounting must reflect
        let mut rng = Rng::new(3);
        let x = rand_mat(&mut rng, 32, 512);
        let rtn = Mxfp4Tensor::quantize(&x, 32, 512, QuantMode::Rtn, &mut rng);
        let quest = Mxfp4Tensor::quantize(&x, 32, 512, QuantMode::Quest, &mut rng);
        let bits = |t: &Mxfp4Tensor| t.storage_bytes() as f64 * 8.0 / (32.0 * 512.0);
        assert!((bits(&rtn) - 4.25).abs() < 1e-9, "{}", bits(&rtn));
        assert!((bits(&quest) - 5.25).abs() < 1e-9, "{}", bits(&quest));
        assert_eq!(
            quest.storage_bytes() - rtn.storage_bytes(),
            32 * 512 / 8,
            "mask must cost one bit per element"
        );
        // shape-independent: an odd-row tensor whose mask payload is not
        // u64-aligned still accounts at exactly one bit per element
        let y = rand_mat(&mut rng, 5, 32);
        let q = Mxfp4Tensor::quantize(&y, 5, 32, QuantMode::Quest, &mut rng);
        let q_bits = q.storage_bytes() as f64 * 8.0 / (5.0 * 32.0);
        assert!((q_bits - 5.25).abs() < 1e-9, "{q_bits}");
    }

    #[test]
    fn gemm_matches_dequantized_reference() {
        let mut rng = Rng::new(4);
        let (m, n, k) = (16, 8, 96);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, n, k);
        let ta = Mxfp4Tensor::quantize(&a, m, k, QuantMode::Rtn, &mut rng);
        let tb = Mxfp4Tensor::quantize(&b, n, k, QuantMode::Rtn, &mut rng);
        let got = mxfp4_gemm(&ta, &tb);
        let want = f32_gemm(&ta.dequantize(), &tb.dequantize(), m, n, k);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn sr_prescaled_unbiased_with_16_9() {
        let mut rng = Rng::new(5);
        let x = rand_mat(&mut rng, 1, 32);
        let mut acc = vec![0.0f64; 32];
        let trials = 4000;
        for _ in 0..trials {
            let t = Mxfp4Tensor::quantize(&x, 1, 32, QuantMode::SrPrescaled, &mut rng);
            for (a, v) in acc.iter_mut().zip(t.dequantize()) {
                *a += v as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let est = (4.0 / 3.0) * a / trials as f64;
            assert!((est - x[i] as f64).abs() < 0.06, "{i}: {est} vs {}", x[i]);
        }
    }

    #[test]
    fn quest_mask_flags_outliers() {
        let mut rng = Rng::new(6);
        let mut x = rand_mat(&mut rng, 1, 32);
        x[3] = 100.0;
        let t = Mxfp4Tensor::quantize(&x, 1, 32, QuantMode::Quest, &mut rng);
        assert!(!t.mask_at(3));
        let kept: usize = (0..32).filter(|&i| t.mask_at(i)).count();
        assert!(kept >= 28);
    }

    #[test]
    fn quest_mse_beats_absmax_on_gaussian() {
        let mut rng = Rng::new(7);
        let x = rand_mat(&mut rng, 64, 512);
        let q = Mxfp4Tensor::quantize(&x, 64, 512, QuantMode::Quest, &mut rng).dequantize();
        let a = Mxfp4Tensor::quantize(&x, 64, 512, QuantMode::Rtn, &mut rng).dequantize();
        let mse_q = crate::util::stats::mse(&q, &x);
        let mse_a = crate::util::stats::mse(&a, &x);
        assert!(mse_q < mse_a, "quest {mse_q} vs absmax {mse_a}");
    }
}
