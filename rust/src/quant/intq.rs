//! Symmetric INT4 quantization (LSS / LUQ-INT4 baselines).

use crate::quant::mxfp4::MX_GROUP;
use crate::util::rng::Rng;

pub const INT4_MAX: f32 = 7.0;

/// AbsMax RTN INT4 per 32-group (quant-dequant).
pub fn int4_rtn(data: &[f32]) -> Vec<f32> {
    assert_eq!(data.len() % MX_GROUP, 0);
    let mut out = vec![0.0f32; data.len()];
    for (g, chunk) in data.chunks(MX_GROUP).enumerate() {
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = amax.max(1e-20) / INT4_MAX;
        for (i, &v) in chunk.iter().enumerate() {
            let q = (v / s).clamp(-INT4_MAX, INT4_MAX);
            let r = (q.abs() + 0.5).floor().copysign(q);
            out[g * MX_GROUP + i] = r * s;
        }
    }
    out
}

/// AbsMax stochastic-rounding INT4 per 32-group (unbiased inside range).
pub fn int4_sr(data: &[f32], rng: &mut Rng) -> Vec<f32> {
    assert_eq!(data.len() % MX_GROUP, 0);
    let mut out = vec![0.0f32; data.len()];
    for (g, chunk) in data.chunks(MX_GROUP).enumerate() {
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = amax.max(1e-20) / INT4_MAX;
        for (i, &v) in chunk.iter().enumerate() {
            let y = (v / s).clamp(-INT4_MAX, INT4_MAX);
            let lo = y.floor();
            let q = if rng.uniform_f32() < y - lo { lo + 1.0 } else { lo };
            out[g * MX_GROUP + i] = q * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_on_integer_grid() {
        let mut rng = Rng::new(1);
        let x = rng.gaussian_vec(128, 1.0);
        let q = int4_rtn(&x);
        for (g, chunk) in x.chunks(32).enumerate() {
            let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = amax.max(1e-20) / INT4_MAX;
            for i in 0..32 {
                let level = q[g * 32 + i] / s;
                assert!((level - level.round()).abs() < 1e-4);
                assert!(level.abs() <= INT4_MAX + 1e-4);
            }
        }
    }

    #[test]
    fn sr_unbiased() {
        let mut rng = Rng::new(2);
        let x = vec![0.33f32; 32];
        let mut acc = 0.0f64;
        let trials = 20_000;
        for _ in 0..trials {
            let q = int4_sr(&x, &mut rng);
            acc += q.iter().map(|&v| v as f64).sum::<f64>() / 32.0;
        }
        assert!((acc / trials as f64 - 0.33).abs() < 3e-3);
    }
}
