//! E8M0 shared-scale format: 8 exponent bits, no mantissa (power-of-two
//! scales), bias 127 — one scale byte per 32-element MX group.

/// An E8M0 scale byte. Stored value is the biased exponent; 0xFF is NaN
/// per the OCP spec and never produced here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E8m0(pub u8);

/// Smallest exponent we emit. The spec floor is -127, but the f32 twin
/// (python formats.py) clamps at -98 because XLA CPU flushes subnormals;
/// the rust side matches so both substrates quantize identically.
pub const MIN_EXP: i32 = -98;
pub const MAX_EXP: i32 = 127;

impl E8m0 {
    /// Scale covering `absmax` into ±target_max: 2^ceil(log2(amax/target)).
    pub fn from_absmax(absmax: f32, target_max: f32) -> E8m0 {
        let safe = absmax.max((MIN_EXP as f32).exp2());
        let exp = (safe / target_max).log2().ceil() as i32;
        E8m0::from_exp(exp)
    }

    pub fn from_exp(exp: i32) -> E8m0 {
        let e = exp.clamp(MIN_EXP, MAX_EXP);
        E8m0((e + 127) as u8)
    }

    #[inline]
    pub fn exp(self) -> i32 {
        self.0 as i32 - 127
    }

    /// The scale value as f32 (always exact: power of two in range).
    #[inline]
    pub fn value(self) -> f32 {
        (self.exp() as f32).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_without_clipping() {
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..10_000 {
            let amax = rng.uniform_f32() * 100.0 + 1e-5;
            let s = E8m0::from_absmax(amax, 6.0).value();
            assert!(amax / s <= 6.0 + 1e-4, "amax={amax} s={s}");
            assert!(amax / s > 3.0 - 1e-4, "scale too coarse: amax={amax} s={s}");
        }
    }

    #[test]
    fn power_of_two() {
        for amax in [0.01f32, 0.5, 1.0, 7.3, 512.0] {
            let v = E8m0::from_absmax(amax, 6.0).value();
            assert_eq!(v.log2().fract(), 0.0);
        }
    }

    #[test]
    fn zero_absmax_safe() {
        let s = E8m0::from_absmax(0.0, 6.0);
        assert!(s.value() > 0.0 && s.value().is_finite());
        assert_eq!(s.exp(), MIN_EXP);
    }

    #[test]
    fn byte_roundtrip() {
        for e in MIN_EXP..=MAX_EXP {
            assert_eq!(E8m0::from_exp(e).exp(), e);
        }
    }
}
