//! FP8 (E4M3) element format + MXFP8 group quantization — the paper's
//! "lossless" baseline precision.

use crate::quant::e8m0::E8m0;
use crate::quant::mxfp4::MX_GROUP;

pub const E4M3_MAX: f32 = 448.0;

/// Round f32 to E4M3, nearest (ties away from zero), clamping to ±448.
/// Matches `formats.e4m3` in python (same min-normal handling).
pub fn e4m3(x: f32) -> f32 {
    if x == 0.0 {
        return 0.0;
    }
    let a = x.abs();
    let bias = 7;
    let min_exp = 1 - bias; // -6
    let e = a.max(1e-38).log2().floor().max(min_exp as f32);
    let ulp = (e - 3.0).exp2();
    let q = ((a / ulp) + 0.5).floor() * ulp;
    let q = q.min(E4M3_MAX);
    if x < 0.0 {
        -q
    } else {
        q
    }
}

/// MXFP8: E4M3 elements + shared E8M0 scale per 32-group (quant-dequant).
pub fn mxfp8_rtn(data: &[f32]) -> Vec<f32> {
    assert_eq!(data.len() % MX_GROUP, 0);
    let mut out = vec![0.0f32; data.len()];
    for (g, chunk) in data.chunks(MX_GROUP).enumerate() {
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = E8m0::from_absmax(amax, E4M3_MAX).value();
        for (i, &v) in chunk.iter().enumerate() {
            out[g * MX_GROUP + i] = e4m3(v / s) * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn representable_values_exact() {
        for v in [1.0f32, 1.125, 240.0, 448.0, 0.015625, -3.5] {
            assert_eq!(e4m3(v), v);
        }
    }

    #[test]
    fn clamps_at_max() {
        assert_eq!(e4m3(1e6), E4M3_MAX);
        assert_eq!(e4m3(-1e6), -E4M3_MAX);
    }

    #[test]
    fn rounding_to_nearest() {
        // at binade [1,2): ulp = 1/8
        assert_eq!(e4m3(1.0 + 1.0 / 32.0), 1.0);
        assert_eq!(e4m3(1.0 + 3.0 / 32.0), 1.125);
    }

    #[test]
    fn mxfp8_error_much_smaller_than_fp4() {
        let mut rng = crate::util::rng::Rng::new(1);
        let x = rng.gaussian_vec(32 * 128, 1.0);
        let q8 = mxfp8_rtn(&x);
        let mut rng2 = crate::util::rng::Rng::new(2);
        let q4 = crate::quant::mxfp4::Mxfp4Tensor::quantize(
            &x, 128, 32, crate::quant::QuantMode::Rtn, &mut rng2,
        )
        .dequantize();
        let e8 = crate::util::stats::mse(&q8, &x);
        let e4 = crate::util::stats::mse(&q4, &x);
        assert!(e8 < e4 / 10.0, "fp8 {e8} vs fp4 {e4}");
    }
}
