//! FP8 (E4M3) element format + MXFP8 group quantization — the paper's
//! "lossless" baseline precision.

use crate::quant::e8m0::E8m0;
use crate::quant::mxfp4::MX_GROUP;

pub const E4M3_MAX: f32 = 448.0;

/// Round f32 to E4M3, nearest (ties away from zero), clamping to ±448.
/// Matches `formats.e4m3` in python (same min-normal handling).
pub fn e4m3(x: f32) -> f32 {
    if x == 0.0 {
        return 0.0;
    }
    let a = x.abs();
    let bias = 7;
    let min_exp = 1 - bias; // -6
    let e = a.max(1e-38).log2().floor().max(min_exp as f32);
    let ulp = (e - 3.0).exp2();
    let q = ((a / ulp) + 0.5).floor() * ulp;
    let q = q.min(E4M3_MAX);
    if x < 0.0 {
        -q
    } else {
        q
    }
}

/// Encode an f32 into the OCP E4M3 byte: 1 sign, 4 exponent (bias 7),
/// 3 mantissa. Nearest, ties away from zero (matching [`e4m3`]); clamps
/// to ±448 (bits 0x7E) and never emits the NaN pattern 0x7F. Both zeros
/// encode as +0 — there is no negative zero on this wire.
pub fn e4m3_encode_bits(x: f32) -> u8 {
    if x == 0.0 {
        return 0;
    }
    let sign = if x < 0.0 { 0x80u8 } else { 0 };
    let a = x.abs().min(E4M3_MAX);
    let e = (a.max(1e-38).log2().floor().max(-6.0)) as i32;
    // m = round(a / 2^(e-3)): 8..=16 for normals, 0..=8 at the e = -6 floor
    let mut m = ((a / ((e - 3) as f32).exp2()) + 0.5).floor() as i32;
    let mut e = e;
    if m >= 16 {
        // rounding carried past the binade top; 16/2 = 8 is the next
        // binade's mantissa floor
        e += 1;
        m = 8;
    }
    if m == 0 {
        return 0; // underflow below half the smallest subnormal
    }
    if m < 8 {
        // subnormal: exponent field 0, value m * 2^-9
        sign | m as u8
    } else {
        sign | (((e + 7) as u8) << 4) | ((m - 8) as u8)
    }
}

/// Decode an E4M3 byte (exact).
pub fn e4m3_decode_bits(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 4) & 0x0F) as i32;
    let mant = (b & 0x07) as i32;
    let mag = if exp == 0 {
        mant as f32 * (-9.0f32).exp2()
    } else {
        (8 + mant) as f32 * ((exp - 10) as f32).exp2()
    };
    sign * mag
}

/// Round UP to the next representable E4M3 magnitude (values already on
/// the grid map to themselves, so this is idempotent), clamping to 448.
/// Used for NVFP4 scale encoding: a ceil-rounded scale guarantees
/// `group_absmax / scale` never exceeds the element grid — the same
/// discipline `E8m0::from_absmax` applies for MX formats.
pub fn e4m3_ceil(x: f32) -> f32 {
    if x <= 0.0 {
        return 0.0;
    }
    let a = x.min(E4M3_MAX);
    let e = a.max(1e-38).log2().floor().max(-6.0);
    let ulp = (e - 3.0).exp2();
    ((a / ulp).ceil() * ulp).min(E4M3_MAX)
}

/// MXFP8: E4M3 elements + shared E8M0 scale per 32-group (quant-dequant).
pub fn mxfp8_rtn(data: &[f32]) -> Vec<f32> {
    assert_eq!(data.len() % MX_GROUP, 0);
    let mut out = vec![0.0f32; data.len()];
    for (g, chunk) in data.chunks(MX_GROUP).enumerate() {
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = E8m0::from_absmax(amax, E4M3_MAX).value();
        for (i, &v) in chunk.iter().enumerate() {
            out[g * MX_GROUP + i] = e4m3(v / s) * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn representable_values_exact() {
        for v in [1.0f32, 1.125, 240.0, 448.0, 0.015625, -3.5] {
            assert_eq!(e4m3(v), v);
        }
    }

    #[test]
    fn clamps_at_max() {
        assert_eq!(e4m3(1e6), E4M3_MAX);
        assert_eq!(e4m3(-1e6), -E4M3_MAX);
    }

    #[test]
    fn rounding_to_nearest() {
        // at binade [1,2): ulp = 1/8
        assert_eq!(e4m3(1.0 + 1.0 / 32.0), 1.0);
        assert_eq!(e4m3(1.0 + 3.0 / 32.0), 1.125);
    }

    #[test]
    fn e4m3_bits_roundtrip_every_byte() {
        for b in 0u16..=255 {
            let b = b as u8;
            if b & 0x7F == 0x7F {
                continue; // the NaN pattern is never produced
            }
            let v = e4m3_decode_bits(b);
            assert!(v.is_finite());
            // every decodable byte re-encodes to itself (modulo -0 -> +0)
            let expect = if b == 0x80 { 0 } else { b };
            assert_eq!(e4m3_encode_bits(v), expect, "byte {b:#04x} value {v}");
            // and the byte codec agrees with the value-level rounder
            assert_eq!(e4m3(v), v);
        }
    }

    #[test]
    fn e4m3_bits_match_value_rounder_on_random_inputs() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..4000 {
            let x = rng.gaussian_f32() * 10f32.powf(rng.uniform_f32() * 6.0 - 3.0);
            assert_eq!(e4m3_decode_bits(e4m3_encode_bits(x)), e4m3(x), "x = {x}");
        }
    }

    #[test]
    fn e4m3_ceil_is_idempotent_and_covers() {
        let mut rng = crate::util::rng::Rng::new(10);
        for _ in 0..4000 {
            let x = rng.uniform_f32() * 500.0 + 1e-6;
            let c = e4m3_ceil(x);
            assert_eq!(e4m3_ceil(c), c, "not idempotent at {x}");
            assert_eq!(e4m3(c), c, "not on grid at {x}");
            if x <= E4M3_MAX {
                assert!(c >= x, "ceil went down at {x}: {c}");
            }
        }
        assert_eq!(e4m3_ceil(0.0), 0.0);
        assert_eq!(e4m3_ceil(-3.0), 0.0);
        assert_eq!(e4m3_ceil(448.0), 448.0);
        assert_eq!(e4m3_ceil(1e9), 448.0);
        assert_eq!(e4m3_ceil(1.0 + 1.0 / 64.0), 1.125);
    }

    #[test]
    fn mxfp8_error_much_smaller_than_fp4() {
        let mut rng = crate::util::rng::Rng::new(1);
        let x = rng.gaussian_vec(32 * 128, 1.0);
        let q8 = mxfp8_rtn(&x);
        let mut rng2 = crate::util::rng::Rng::new(2);
        let q4 = crate::quant::mxfp4::Mxfp4Tensor::quantize(
            &x, 128, 32, crate::quant::QuantMode::Rtn, &mut rng2,
        )
        .dequantize();
        let e8 = crate::util::stats::mse(&q8, &x);
        let e4 = crate::util::stats::mse(&q4, &x);
        assert!(e8 < e4 / 10.0, "fp8 {e8} vs fp4 {e4}");
    }
}
