//! FP4 E2M1 element format: 1 sign, 2 exponent, 1 mantissa bit.
//!
//! Representable magnitudes: 0, 0.5, 1, 1.5, 2, 3, 4, 6 — the value grid
//! Blackwell's tensor cores consume for MXFP4/NVFP4 operands.

/// Non-negative E2M1 magnitudes, indexed by the low 3 bits of the code.
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

pub const E2M1_MAX: f32 = 6.0;

/// Deterministic round-to-nearest on the E2M1 grid (ties away from zero),
/// clamping to ±6. Bit-identical to `formats.e2m1_rtn` in python.
pub fn e2m1_rtn(x: f32) -> f32 {
    let a = x.abs();
    let step = if a < 2.0 {
        0.5
    } else if a < 4.0 {
        1.0
    } else {
        2.0
    };
    let q = ((a / step) + 0.5).floor() * step;
    let q = q.min(E2M1_MAX);
    if x.is_sign_negative() {
        -q
    } else {
        q
    }
}

/// Stochastic rounding on the grid given uniform noise `u ∈ [0,1)`.
/// For |x| ≤ 6, `E_u[e2m1_sr(x, U)] == x` exactly (the Quartet backward
/// relies on this; the 3/4 pre-scaling guarantees the domain).
pub fn e2m1_sr(x: f32, u: f32) -> f32 {
    let a = x.abs().clamp(0.0, E2M1_MAX);
    let step = if a < 2.0 {
        0.5
    } else if a < 4.0 {
        1.0
    } else {
        2.0
    };
    let lo = (a / step).floor() * step;
    let step_lo = if lo < 2.0 {
        0.5
    } else if lo < 4.0 {
        1.0
    } else {
        2.0
    };
    let hi = (lo + step_lo).min(E2M1_MAX);
    let frac = if hi > lo { (a - lo) / (hi - lo) } else { 0.0 };
    let q = if u < frac { hi } else { lo };
    if x.is_sign_negative() {
        -q
    } else {
        q
    }
}

/// Grid value → 3-bit index, arithmetically (2·q is 0,1,2,3,4,6,8,12 →
/// codes 0..7; §Perf: replaces the 8-entry linear scan that dominated the
/// quantize stage profile).
#[inline]
fn grid_index(mag: f32) -> u8 {
    let twice = (2.0 * mag) as u32;
    match twice {
        0..=4 => twice as u8,
        6 => 5,
        8 => 6,
        _ => 7, // 12 (=6.0)
    }
}

/// Encode a (pre-scaled) value to its 4-bit code: bit 3 = sign, bits 0..2
/// index [`E2M1_GRID`]. Uses RTN.
#[inline]
pub fn e2m1_encode_rtn(x: f32) -> u8 {
    let q = e2m1_rtn(x);
    let sign = if q.is_sign_negative() || (q == 0.0 && x < 0.0) { 8u8 } else { 0 };
    sign | grid_index(q.abs())
}

/// Encode with stochastic rounding (value must already be |x| ≤ 6).
#[inline]
pub fn e2m1_encode_sr(x: f32, u: f32) -> u8 {
    let q = e2m1_sr(x, u);
    let sign = if q.is_sign_negative() || (q == 0.0 && x < 0.0) { 8u8 } else { 0 };
    sign | grid_index(q.abs())
}

/// Decode a 4-bit code back to f32.
#[inline]
pub fn e2m1_decode(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 7) as usize];
    if code & 8 != 0 {
        -mag
    } else {
        mag
    }
}

/// Two-values-per-byte decode LUT (low nibble first): the packed-GEMM hot
/// path decodes a whole byte with one table lookup.
pub fn byte_decode_lut() -> [(f32, f32); 256] {
    let mut lut = [(0.0f32, 0.0f32); 256];
    for (b, entry) in lut.iter_mut().enumerate() {
        *entry = (e2m1_decode((b & 0xf) as u8), e2m1_decode((b >> 4) as u8));
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_nearest_and_ties() {
        assert_eq!(e2m1_rtn(0.26), 0.5);
        assert_eq!(e2m1_rtn(0.24), 0.0);
        assert_eq!(e2m1_rtn(0.25), 0.5); // tie away from zero
        assert_eq!(e2m1_rtn(2.5), 3.0);
        assert_eq!(e2m1_rtn(-2.5), -3.0);
        assert_eq!(e2m1_rtn(5.0), 6.0);
        assert_eq!(e2m1_rtn(100.0), 6.0);
        assert_eq!(e2m1_rtn(-100.0), -6.0);
    }

    #[test]
    fn sr_bounds_and_unbiasedness() {
        // value 1.7 lies between 1.5 and 2.0
        let mut ups = 0usize;
        let n = 100_000;
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..n {
            let q = e2m1_sr(1.7, rng.uniform_f32());
            assert!(q == 1.5 || q == 2.0);
            if q == 2.0 {
                ups += 1;
            }
        }
        let mean = (ups as f64 * 2.0 + (n - ups) as f64 * 1.5) / n as f64;
        assert!((mean - 1.7).abs() < 5e-3, "{mean}");
    }

    #[test]
    fn encode_decode_roundtrip_all_codes() {
        for code in 0u8..16 {
            let v = e2m1_decode(code);
            let back = e2m1_encode_rtn(v);
            // -0.0 and +0.0 decode equal; compare by value
            assert_eq!(e2m1_decode(back), v);
        }
    }

    #[test]
    fn encode_matches_rtn() {
        let mut rng = crate::util::rng::Rng::new(4);
        for _ in 0..10_000 {
            let x = rng.gaussian_f32() * 3.0;
            assert_eq!(e2m1_decode(e2m1_encode_rtn(x)), e2m1_rtn(x));
        }
    }

    #[test]
    fn byte_lut_consistent() {
        let lut = byte_decode_lut();
        assert_eq!(lut[0x10].0, 0.0); // low nibble 0 -> 0.0
        assert_eq!(lut[0x10].1, 0.5); // high nibble 1 -> grid[1] = 0.5
        assert_eq!(lut[0x9f].0, -6.0); // low nibble 0xf -> sign|grid[7]... 0xf = -6
        assert_eq!(lut[0x9f].1, -0.5); // high nibble 0x9 -> -grid[1]
    }

    #[test]
    fn byte_lut_values() {
        let lut = byte_decode_lut();
        for b in 0..256usize {
            assert_eq!(lut[b].0, e2m1_decode((b & 0xf) as u8));
            assert_eq!(lut[b].1, e2m1_decode((b >> 4) as u8));
        }
    }
}
