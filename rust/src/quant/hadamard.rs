//! Block Hadamard transforms (rust twin of `python/compile/hadamard.py`).
//!
//! Two execution strategies, both exercised by the Fig 3/Fig 5 benches:
//!
//! * **matmul form** — multiply each 32-group by the dense normalized H₃₂
//!   (what the GPU kernel and the Pallas kernel do: Hadamard as a GEMM);
//! * **FWHT form** — in-place O(g log g) butterflies, the fast CPU path
//!   the coordinator actually uses on the hot loop.
//!
//! Both are bit-comparable up to f32 reassociation; tests pin them equal
//! within 1e-5 and pin FWHT against the dense definition.

use crate::util::rng::Rng;

/// Dense normalized Sylvester Hadamard matrix H_g (g a power of two),
/// row-major.
pub fn hadamard_matrix(g: usize) -> Vec<f32> {
    assert!(g.is_power_of_two() && g > 0, "g must be a power of two");
    let mut h = vec![1.0f32];
    let mut size = 1;
    while size < g {
        let mut next = vec![0.0f32; 4 * size * size];
        for r in 0..size {
            for c in 0..size {
                let v = h[r * size + c];
                next[r * 2 * size + c] = v;
                next[r * 2 * size + size + c] = v;
                next[(size + r) * 2 * size + c] = v;
                next[(size + r) * 2 * size + size + c] = -v;
            }
        }
        h = next;
        size *= 2;
    }
    let norm = 1.0 / (g as f32).sqrt();
    h.iter_mut().for_each(|v| *v *= norm);
    h
}

/// In-place fast Walsh–Hadamard transform of one g-length block
/// (normalized). O(g log g).
pub fn fwht(block: &mut [f32]) {
    let g = block.len();
    debug_assert!(g.is_power_of_two());
    let mut h = 1;
    while h < g {
        let mut i = 0;
        while i < g {
            for j in i..i + h {
                let (x, y) = (block[j], block[j + h]);
                block[j] = x + y;
                block[j + h] = x - y;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let norm = 1.0 / (g as f32).sqrt();
    block.iter_mut().for_each(|v| *v *= norm);
}

/// Apply H_g to each contiguous g-group along the last axis (FWHT path).
pub fn block_hadamard(data: &mut [f32], g: usize) {
    assert_eq!(data.len() % g, 0);
    for chunk in data.chunks_mut(g) {
        fwht(chunk);
    }
}

/// Inverse block transform. Sylvester H is symmetric and orthogonal, so
/// H⁻¹ = H — provided for readability at call sites.
pub fn block_hadamard_inv(data: &mut [f32], g: usize) {
    block_hadamard(data, g);
}

/// Reusable transform plan: caches the dense matrix for the matmul path
/// and carries the group size (mirrors the Pallas kernel's BlockSpec).
pub struct BlockHadamard {
    pub g: usize,
    dense: Vec<f32>,
}

impl BlockHadamard {
    pub fn new(g: usize) -> BlockHadamard {
        BlockHadamard { g, dense: hadamard_matrix(g) }
    }

    /// Matmul-form transform (out-of-place): per group, y = x · H.
    /// This is the arithmetic the GPU Stage-1 kernel performs on the MXU.
    pub fn apply_matmul(&self, data: &[f32]) -> Vec<f32> {
        assert_eq!(data.len() % self.g, 0);
        let g = self.g;
        let mut out = vec![0.0f32; data.len()];
        for (i, chunk) in data.chunks(g).enumerate() {
            let dst = &mut out[i * g..(i + 1) * g];
            for c in 0..g {
                let mut acc = 0.0f32;
                for r in 0..g {
                    acc += chunk[r] * self.dense[r * g + c];
                }
                dst[c] = acc;
            }
        }
        out
    }

    /// FWHT-form transform (in-place) — the coordinator's fast path.
    pub fn apply_fwht(&self, data: &mut [f32]) {
        block_hadamard(data, self.g);
    }
}

/// Rademacher sign vector of length d for the randomized transform Ĥ.
pub fn rademacher(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.rademacher()).collect()
}

/// Randomized block Hadamard Ĥ(x, ξ) = H·diag(ξ)·x applied per g-group
/// along rows of a [rows, d] row-major matrix (in place), on an explicit
/// [`crate::kernels::Backend`] — the native trainer passes its own; the
/// `randomized_block_hadamard` free function below routes through the
/// process-wide backend.
pub fn randomized_block_hadamard_on(
    be: &dyn crate::kernels::Backend,
    data: &mut [f32],
    signs: &[f32],
    g: usize,
) {
    let d = signs.len();
    assert_eq!(data.len() % d, 0);
    for row in data.chunks_mut(d) {
        for (v, s) in row.iter_mut().zip(signs) {
            *v *= s;
        }
    }
    be.block_hadamard(data, g);
}

/// Inverse of the randomized transform on an explicit backend:
/// diag(ξ)·H⁻¹·y.
pub fn randomized_block_hadamard_inv_on(
    be: &dyn crate::kernels::Backend,
    data: &mut [f32],
    signs: &[f32],
    g: usize,
) {
    let d = signs.len();
    assert_eq!(data.len() % d, 0);
    be.block_hadamard(data, g);
    for row in data.chunks_mut(d) {
        for (v, s) in row.iter_mut().zip(signs) {
            *v *= s;
        }
    }
}

/// [`randomized_block_hadamard_on`] through the active backend.
pub fn randomized_block_hadamard(data: &mut [f32], signs: &[f32], g: usize) {
    randomized_block_hadamard_on(crate::kernels::active(), data, signs, g);
}

/// [`randomized_block_hadamard_inv_on`] through the active backend.
pub fn randomized_block_hadamard_inv(data: &mut [f32], signs: &[f32], g: usize) {
    randomized_block_hadamard_inv_on(crate::kernels::active(), data, signs, g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_orthogonal() {
        for g in [2usize, 8, 32] {
            let h = hadamard_matrix(g);
            for i in 0..g {
                for j in 0..g {
                    let dot: f32 = (0..g).map(|k| h[i * g + k] * h[j * g + k]).sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-5, "g={g} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn fwht_matches_dense() {
        let mut rng = Rng::new(3);
        for g in [4usize, 32, 64] {
            let x = rng.gaussian_vec(g, 1.0);
            let plan = BlockHadamard::new(g);
            let dense = plan.apply_matmul(&x);
            let mut fast = x.clone();
            fwht(&mut fast);
            for (a, b) in dense.iter().zip(&fast) {
                assert!((a - b).abs() < 1e-4, "g={g}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn self_inverse() {
        let mut rng = Rng::new(4);
        let x = rng.gaussian_vec(128, 1.0);
        let mut y = x.clone();
        block_hadamard(&mut y, 32);
        block_hadamard_inv(&mut y, 32);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn randomized_cancels_in_contraction() {
        let mut rng = Rng::new(5);
        let d = 64;
        let signs = rademacher(&mut rng, d);
        let g = rng.gaussian_vec(d, 1.0);
        let w = rng.gaussian_vec(d, 1.0);
        let want: f32 = g.iter().zip(&w).map(|(a, b)| a * b).sum();
        let (mut gh, mut wh) = (g.clone(), w.clone());
        randomized_block_hadamard(&mut gh, &signs, 32);
        randomized_block_hadamard(&mut wh, &signs, 32);
        let got: f32 = gh.iter().zip(&wh).map(|(a, b)| a * b).sum();
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn randomized_roundtrip() {
        let mut rng = Rng::new(6);
        let signs = rademacher(&mut rng, 64);
        let x = rng.gaussian_vec(2 * 64, 1.0);
        let mut y = x.clone();
        randomized_block_hadamard(&mut y, &signs, 32);
        randomized_block_hadamard_inv(&mut y, &signs, 32);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn spreads_outliers() {
        let mut x = vec![0.0f32; 32];
        x[5] = 32.0;
        block_hadamard(&mut x, 32);
        let expect = 32.0 / (32.0f32).sqrt();
        for v in &x {
            assert!((v.abs() - expect).abs() < 1e-4);
        }
    }
}
