//! `repro` — the Quartet reproduction CLI (Layer-3 leader entrypoint).
//!
//! ```text
//! repro info                          # engine + artifact inventory (xla)
//! repro train   --native --method quartet [--arch mlp|transformer]
//!               [--steps 400] [--d-hidden 128 | --d-model 64 --n-heads 4
//!               --n-layers 2 --d-ff 128 --seq 32]
//!               [--workers 4] [--reduce f32|mxfp4] [--shards 4]
//!               [--tp 2] [--pp 2] [--ts 2] [--wire f32|mxfp4]
//!               [--checkpoint ckpt.json] [--out runs]    # pure Rust
//! repro train   --artifact n80k-quartet --steps 200 [--lr 2e-3] [--seed 0]
//! repro sweep   --native [--preset smoke|native] [--out runs]  # pure Rust
//! repro sweep   --preset reduced --out runs [--max-steps 4000]   # PJRT
//! repro convert-ckpt --checkpoint ckpt.json --out ckpt.qckpt
//!               [--method quartet]          # JSON -> binary packed-MXFP4
//! repro serve   [--checkpoint ckpt.json|ckpt.qckpt] --method quartet [--max-batch 8]
//!               [--arch mlp|transformer] [--recompute]
//!               [--kv-page-size 16] [--kv-quant f32|mxfp4]
//!               [--prefill-chunk 8] [--kv-pool-bytes N]
//!               [--no-prefix-share] [--shared-prefix-len 32]
//!               [--requests 64] [--rate 40] [--trace trace.json]
//!               [--temperature 0.8] [--out runs]   # native, pure Rust
//! repro serve   --artifact n330k-quartet --requests 256       # PJRT
//! repro regions [--paper]             # Fig 1(b,c) optimality maps
//! repro table2                        # error-bias statistics
//! repro kernels [--m 256 --n 11008 --k 4096]   # backend speedup check
//! repro check-records [--dir runs]    # bench-record schema + perf gate
//! ```
//!
//! Every subcommand honours the global `--backend
//! scalar|parallel|simd|parallel+simd` flag (or the `QUARTET_BACKEND`
//! env var) selecting the kernels backend.
//! `train --native` runs the pure-Rust Quartet trainer and `serve`
//! without `--artifact` runs the native continuous-batching engine; both
//! share one method axis.
//! `convert-ckpt` packs a JSON checkpoint into the versioned binary
//! format (`docs/CHECKPOINT_FORMAT.md`); `serve --checkpoint` sniffs the
//! magic and loads binary checkpoints with zero weight-prep passes.
//! The axis is
//! (`f32|mxfp8|quartet|rtn|nvfp4|fp4-clamp`, see
//! [`quartet::quant::format::Method`]). `sweep --native` trains that
//! axis across MLP widths and refits the scaling law from the records.
//! Artifact-based `train`/`sweep`/`serve`/`info` execute through PJRT
//! and need `--features xla`; the rest are pure Rust.

use anyhow::{bail, Result};

use quartet::util::cli::Args;

use std::path::PathBuf;

#[cfg(feature = "xla")]
use quartet::coordinator::sweep::{run_sweep, sweep_presets};
#[cfg(feature = "xla")]
use quartet::coordinator::trainer::{train_artifact, TrainOptions};
#[cfg(feature = "xla")]
use quartet::runtime::engine::Engine;

#[cfg(feature = "xla")]
fn artifacts_root(args: &mut Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    quartet::util::cli::apply_backend_flag(&mut args)?;
    match args.subcommand().map(str::to_string).as_deref() {
        Some("info") => cmd_info(&mut args),
        Some("train") => cmd_train(&mut args),
        Some("sweep") => cmd_sweep(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("convert-ckpt") => cmd_convert_ckpt(&mut args),
        Some("regions") => cmd_regions(&mut args),
        Some("table2") => cmd_table2(&mut args),
        Some("kernels") => cmd_kernels(&mut args),
        Some("check-records") => cmd_check_records(&mut args),
        Some(other) => bail!("unknown subcommand {other:?} (see --help in README)"),
        None => {
            println!(
                "usage: repro <info|train|sweep|serve|convert-ckpt|regions|table2|kernels|\
                 check-records> [flags]"
            );
            let axis = quartet::quant::format::Method::axis_help();
            println!("       repro train --native --method {axis}");
            println!("                   [--arch mlp|transformer]");
            println!("                   [--workers N --reduce f32|mxfp4 --shards S]");
            println!("                   [--tp T --pp P --ts S --wire f32|mxfp4]  (pure Rust)");
            println!("       repro sweep --native [--preset smoke|native] [--out DIR] (pure Rust)");
            println!("       repro serve --method {axis} [--checkpoint ckpt.json]");
            println!("                   [--arch mlp|transformer] [--recompute]");
            println!("                   [--kv-page-size 16 --kv-quant f32|mxfp4]");
            println!("                   [--prefill-chunk C --kv-pool-bytes N --no-prefix-share]");
            println!("                   [--trace t.json | --requests N --rate r]  (pure Rust)");
            println!("       repro convert-ckpt --checkpoint ckpt.json --out ckpt.qckpt");
            println!("                   [--method {axis}]  (JSON -> binary packed)");
            println!(
                "global: --backend scalar|parallel|simd|parallel+simd (or QUARTET_BACKEND env)"
            );
            println!("see README.md for the full command reference");
            Ok(())
        }
    }
}

#[cfg(not(feature = "xla"))]
fn no_xla(what: &str) -> Result<()> {
    bail!(
        "`{what}` executes through the PJRT runtime, which this binary was \
         built without — rebuild with `cargo build --features xla` (see README.md)"
    )
}

#[cfg(feature = "xla")]
fn cmd_info(args: &mut Args) -> Result<()> {
    let root = artifacts_root(args);
    args.finish()?;
    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());
    println!("kernels backend: {}", quartet::kernels::active().name());
    println!("artifacts root: {}", root.display());
    if let Ok(read) = std::fs::read_dir(&root) {
        for e in read.flatten() {
            let dir = e.path();
            if dir.join("manifest.json").exists() {
                match engine.load_artifact(&dir) {
                    Ok(a) => {
                        let m = &a.manifest;
                        println!(
                            "  {:<24} {:>10} non-emb params  d={} L={} method={} eps=[{}]",
                            m.name,
                            m.non_embedding_params,
                            m.model.d_model,
                            m.model.n_layers,
                            m.model.method,
                            m.entrypoints.keys().cloned().collect::<Vec<_>>().join(",")
                        );
                    }
                    Err(e) => println!("  {:<24} INVALID: {e:#}", dir.display()),
                }
            }
        }
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_info(_args: &mut Args) -> Result<()> {
    no_xla("info")
}

/// `train` front door: `--native` runs the pure-Rust trainer, otherwise
/// the PJRT artifact trainer (xla feature).
fn cmd_train(args: &mut Args) -> Result<()> {
    if args.flag("native") {
        return cmd_train_native(args);
    }
    cmd_train_xla(args)
}

/// Pure-Rust Quartet training (Algorithm 1 on the kernels backends):
/// trains a native model — `--arch mlp` (order-2 MLP LM, the default) or
/// `--arch transformer` (Llama-style decoder with KV-cache-servable
/// checkpoints) — on the synthetic corpus, optionally writing a RunRecord
/// (`--out`) and a servable checkpoint (`--checkpoint`).
fn cmd_train_native(args: &mut Args) -> Result<()> {
    use quartet::train::{
        train_native, train_native_transformer, DistOptions, ModelConfig,
        NativeTrainOptions, ReduceMode, Topology, TrainMethod, TransformerConfig,
        DEFAULT_GRAD_SHARDS,
    };

    let arch = args.str_or("arch", "mlp");
    let method = TrainMethod::parse(&args.str_or("method", "quartet"))?;
    let vocab = args.parse_or("vocab", 256usize)?;
    // data-parallel axis: engaged by any of --workers/--reduce/--shards;
    // --shards fixes the determinism granularity (loss bits depend on it,
    // never on the worker count)
    let workers = args.parse_opt::<usize>("workers")?;
    let reduce = args.get("reduce");
    let shards = args.parse_opt::<usize>("shards")?;
    let dist = if workers.is_some() || reduce.is_some() || shards.is_some() {
        Some(DistOptions {
            workers: workers.unwrap_or(1).max(1),
            shards: shards.unwrap_or(DEFAULT_GRAD_SHARDS),
            reduce: match reduce.as_deref() {
                None => ReduceMode::F32,
                Some(s) => ReduceMode::parse(s)?,
            },
        })
    } else {
        None
    };
    // tensor/pipeline axes: engaged by any of --tp/--pp/--ts/--wire.
    // --ts fixes the logical tensor-shard count (loss bits depend on ts
    // and the wire format, never on the tp/pp placement); it defaults to
    // the requested --tp so the common case needs one flag.
    let tp = args.parse_opt::<usize>("tp")?;
    let pp = args.parse_opt::<usize>("pp")?;
    let ts = args.parse_opt::<usize>("ts")?;
    let wire = args.get("wire");
    let topo = if tp.is_some() || pp.is_some() || ts.is_some() || wire.is_some() {
        Some(Topology {
            ts: ts.or(tp).unwrap_or(1).max(1),
            tp: tp.unwrap_or(1).max(1),
            pp: pp.unwrap_or(1).max(1),
            wire: match wire.as_deref() {
                None => ReduceMode::F32,
                Some(s) => ReduceMode::parse(s)?,
            },
        })
    } else {
        None
    };
    let opts = NativeTrainOptions {
        steps: args.parse_or("steps", 400usize)?,
        batch: args.parse_or("batch", 32usize)?,
        lr: args.parse_or("lr", 8e-3f32)?,
        seed: args.parse_or("seed", 0u64)?,
        eval_every: args.parse_or("eval-every", 0usize)?,
        eval_batches: args.parse_or("eval-batches", 8usize)?,
        log_every: args.parse_or("log-every", 50usize)?,
        verbose: true,
        dist,
        topo,
        ..NativeTrainOptions::default()
    };
    let out = args.get("out").map(PathBuf::from);
    let ckpt = args.get("checkpoint").map(PathBuf::from);

    let be = quartet::kernels::active();
    let (rec, model) = match arch.as_str() {
        "mlp" => {
            let cfg = ModelConfig {
                vocab,
                d_emb: args.parse_or("d-emb", 32usize)?,
                d_hidden: args.parse_or("d-hidden", 128usize)?,
                n_hidden: args.parse_or("n-hidden", 1usize)?,
                method,
            };
            args.finish()?;
            let (rec, m) = train_native(&cfg, &opts, be)?;
            (rec, quartet::train::NativeModel::Mlp(m))
        }
        "transformer" => {
            let cfg = TransformerConfig {
                vocab,
                d_model: args.parse_or("d-model", 64usize)?,
                n_heads: args.parse_or("n-heads", 4usize)?,
                n_layers: args.parse_or("n-layers", 2usize)?,
                d_ff: args.parse_or("d-ff", 128usize)?,
                seq: args.parse_or("seq", 32usize)?,
                method,
            };
            args.finish()?;
            let (rec, m) = train_native_transformer(&cfg, &opts, be)?;
            (rec, quartet::train::NativeModel::Transformer(m))
        }
        other => bail!("unknown --arch {other:?} (expected mlp|transformer)"),
    };
    println!(
        "trained {} [{} backend]: steps={} tokens={} init val loss={:.4} \
         final val loss={:.4} ({:.0} tok/s, {:.2}s){}",
        rec.artifact,
        be.describe(),
        rec.steps,
        rec.tokens,
        rec.val_curve.first().map(|&(_, l)| l).unwrap_or(f64::NAN),
        rec.final_val_loss,
        rec.tokens_per_sec,
        rec.wall_secs,
        if rec.diverged { "  [DIVERGED]" } else { "" }
    );
    if rec.workers > 1 || rec.reduce != "none" {
        println!(
            "dist: workers={} shards={} reduce={} comms={:.1} KiB/step (ring all-reduce, \
             {} bits/value)",
            rec.workers,
            rec.grad_shards,
            rec.reduce,
            rec.comms_allreduce_bytes_per_step / 1024.0,
            if rec.reduce == "mxfp4" { "4.25" } else { "32" }
        );
    }
    if rec.tp > 1 || rec.pp > 1 || rec.wire != "none" {
        println!(
            "topo: tp={} pp={} wire={} rs={:.1} ag={:.1} p2p={:.1} KiB/step \
             (total {:.1} KiB/step across all collectives)",
            rec.tp,
            rec.pp,
            rec.wire,
            rec.comms_reduce_scatter_bytes_per_step / 1024.0,
            rec.comms_all_gather_bytes_per_step / 1024.0,
            rec.comms_p2p_bytes_per_step / 1024.0,
            rec.comms_bytes_per_step / 1024.0
        );
    }
    if let Some(dir) = out {
        let path = rec.save(&dir)?;
        println!("record: {}", path.display());
    }
    if let Some(path) = ckpt {
        if rec.diverged {
            bail!(
                "run diverged — refusing to write checkpoint {} (the weights are garbage; \
                 lower --lr or change --seed)",
                path.display()
            );
        }
        model.save(&path)?;
        println!("checkpoint: {} (serve it with `repro serve --checkpoint`)", path.display());
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_train_xla(args: &mut Args) -> Result<()> {
    let root = artifacts_root(args);
    let artifact = args.required("artifact")?;
    let opts = TrainOptions {
        steps: args.parse_or("steps", 200usize)?,
        lr: args.get("lr").map(|v| v.parse()).transpose()?,
        seed: args.parse_or("seed", 0u64)?,
        eval_every: args.parse_or("eval-every", 0usize)?,
        eval_batches: args.parse_or("eval-batches", 4usize)?,
        log_every: args.parse_or("log-every", 25usize)?,
        use_segments: !args.flag("no-segments"),
        verbose: true,
    };
    let out = args.get("out").map(PathBuf::from);
    args.finish()?;

    let rec = train_artifact(&root, &artifact, opts)?;
    println!(
        "trained {}: steps={} tokens={} final val loss={:.4} ({:.1} tok/s, {:.1}s){}",
        rec.artifact,
        rec.steps,
        rec.tokens,
        rec.final_val_loss,
        rec.tokens_per_sec,
        rec.wall_secs,
        if rec.diverged { "  [DIVERGED]" } else { "" }
    );
    if let Some(dir) = out {
        let path = rec.save(&dir)?;
        println!("record: {}", path.display());
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_train_xla(_args: &mut Args) -> Result<()> {
    no_xla("train (artifact mode; `train --native` is pure Rust)")
}

/// `sweep` front door: `--native` runs the pure-Rust method × width grid
/// and refits the scaling law from its records; otherwise the PJRT
/// artifact sweep (xla feature).
fn cmd_sweep(args: &mut Args) -> Result<()> {
    if args.flag("native") {
        return cmd_sweep_native(args);
    }
    cmd_sweep_xla(args)
}

/// Native sweep: the shared method axis × MLP widths through the
/// pure-Rust trainer (resumable — existing records are reused), followed
/// by the native scaling-law refit: base law on the f32 runs, per-method
/// parameter/data efficiencies on everything else, through the same
/// `scaling::fit` the PJRT sweeps use.
fn cmd_sweep_native(args: &mut Args) -> Result<()> {
    use quartet::coordinator::sweep::{native_sweep_presets, run_native_sweep};
    use quartet::scaling::fit::{fit_base_law, fit_efficiencies, FitOptions};
    use quartet::scaling::law::Run;

    let preset = args.str_or("preset", "smoke");
    let out = PathBuf::from(args.str_or("out", "runs"));
    let verbose = !args.flag("quiet");
    args.finish()?;

    let jobs = native_sweep_presets(&preset)?;
    let be = quartet::kernels::active();
    println!(
        "native sweep {preset:?}: {} jobs [{} backend] -> {}",
        jobs.len(),
        be.describe(),
        out.display()
    );
    let recs = run_native_sweep(&out, &jobs, be, verbose)?;
    println!(
        "{:<24} {:>8} {:>7} {:>10} {:>10}",
        "artifact", "method", "steps", "val loss", "tok/s"
    );
    for r in &recs {
        println!(
            "{:<24} {:>8} {:>7} {:>10.4} {:>10.0}{}",
            r.artifact,
            r.method,
            r.steps,
            r.final_val_loss,
            r.tokens_per_sec,
            if r.diverged { "  [DIVERGED]" } else { "" }
        );
    }

    // ---- native scaling-law refit over the records ---------------------
    let runs: Vec<Run> = recs.iter().filter(|r| !r.diverged).map(|r| r.to_fit_run()).collect();
    let base: Vec<Run> = runs.iter().filter(|r| r.method == "f32").cloned().collect();
    if base.len() >= 3 {
        let fit_opts = FitOptions { max_iters: 1500, restarts: 2, ..FitOptions::default() };
        let (law, obj) = fit_base_law(&base, &fit_opts);
        println!(
            "\n[scaling::fit over {} native runs ({} f32 baseline)]  huber obj {obj:.3e}",
            runs.len(),
            base.len()
        );
        println!(
            "base law: A={:.3e} α={:.3} B={:.3e} β={:.3} E={:.3} γ={:.3}",
            law.a, law.alpha, law.b, law.beta, law.e, law.gamma
        );
        let eff = fit_efficiencies(&law, &runs, &fit_opts);
        println!(
            "{:<10} {:>8} {:>8}   (paper scale: quartet 0.64/0.94)",
            "method", "eff_N", "eff_D"
        );
        for (m, e) in &eff {
            println!("{:<10} {:>8.3} {:>8.3}", m, e.eff_n, e.eff_d);
        }
    } else {
        println!(
            "\n[refit skipped — the {preset:?} preset trains {} f32 width(s); \
             use `--preset native` (3 widths) for a base-law fit]",
            base.len()
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_sweep_xla(args: &mut Args) -> Result<()> {
    let root = artifacts_root(args);
    let preset = args.str_or("preset", "reduced");
    let out = PathBuf::from(args.str_or("out", "runs"));
    let max_steps = args.parse_or("max-steps", 6000usize)?;
    let verbose = !args.flag("quiet");
    args.finish()?;

    let jobs = sweep_presets(&preset)?;
    println!("sweep {preset:?}: {} jobs -> {}", jobs.len(), out.display());
    let recs = run_sweep(&root, &out, &jobs, max_steps, verbose)?;
    println!("{:<22} {:>8} {:>10} {:>10}", "artifact", "ratio", "val loss", "tok/s");
    for r in &recs {
        println!(
            "{:<22} {:>8.0} {:>10.4} {:>10.0}{}",
            r.artifact, r.ratio, r.final_val_loss, r.tokens_per_sec,
            if r.diverged { "  [DIVERGED]" } else { "" }
        );
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_sweep_xla(_args: &mut Args) -> Result<()> {
    no_xla("sweep (artifact mode; `sweep --native` is pure Rust)")
}

/// `serve` front door: with `--artifact` the PJRT prefill engine (xla
/// feature); otherwise the native continuous-batching autoregressive
/// engine over a trained checkpoint (or fresh weights).
fn cmd_serve(args: &mut Args) -> Result<()> {
    match args.get("artifact") {
        Some(artifact) => cmd_serve_xla(args, &artifact),
        None => cmd_serve_native(args),
    }
}

/// Native serving: checkpoint → [`quartet::serve::PackedWeightCache`]
/// (weights prepared exactly once — or ZERO times when `--checkpoint` is
/// a binary packed checkpoint, sniffed by magic and sliced directly) →
/// `ServeEngine` autoregressive decode with admission/eviction between
/// steps. Requests come from a JSON trace (`--trace`) or a synthetic
/// Poisson workload (`--requests`/`--rate`).
fn cmd_serve_native(args: &mut Args) -> Result<()> {
    use quartet::serve::{
        load_trace, synth_requests, KvQuant, KvServeOptions, PackedCheckpoint,
        PackedWeightCache, Sampling, ServeEngine, ServeMethod, ServeRecord, SynthOptions,
    };
    use quartet::train::{
        MlpLm, ModelConfig, NativeModel, TrainMethod, TransformerConfig, TransformerLm,
    };

    let method_flag = args.get("method");
    let method = ServeMethod::parse(method_flag.as_deref().unwrap_or("quartet"))?;
    let max_batch = args.parse_or("max-batch", 8usize)?;
    if max_batch == 0 {
        bail!("--max-batch must be positive");
    }
    let max_new = args.parse_or("max-new-tokens", 32usize)?;
    let temperature = args.parse_or("temperature", 0.0f32)?;
    let seed = args.parse_or("seed", 0u64)?;
    let n_requests = args.parse_or("requests", 64usize)?;
    let prompt_len = args.parse_or("prompt-len", 8usize)?;
    let rate = args.parse_or("rate", 0.0f64)?;
    let stop_token = args.parse_opt::<i32>("stop-token")?;
    let steps_cap = args.parse_opt::<usize>("steps")?;
    let recompute = args.flag("recompute");
    // paged-KV knobs (transformer, cached mode)
    let kv_page_size = args.parse_or("kv-page-size", 16usize)?;
    if kv_page_size == 0 {
        bail!("--kv-page-size must be positive");
    }
    let kv_quant = KvQuant::parse(&args.str_or("kv-quant", "f32"))?;
    let prefill_chunk = args.parse_or("prefill-chunk", 0usize)?;
    let kv_pool_bytes = args.parse_or("kv-pool-bytes", 0usize)?;
    let no_prefix_share = args.flag("no-prefix-share");
    let shared_prefix_len = args.parse_or("shared-prefix-len", 0usize)?;
    let ckpt = args.get("checkpoint").map(PathBuf::from);
    let trace_path = args.get("trace").map(PathBuf::from);
    let out = args.get("out").map(PathBuf::from);
    // fresh-weights shape, ignored when --checkpoint is given (the
    // checkpoint's own `kind` then selects the architecture)
    let arch = args.str_or("arch", "mlp");
    let vocab = args.parse_or("vocab", 256usize)?;
    let d_emb = args.parse_or("d-emb", 32usize)?;
    let d_hidden = args.parse_or("d-hidden", 128usize)?;
    let n_hidden = args.parse_or("n-hidden", 1usize)?;
    let d_model = args.parse_or("d-model", 64usize)?;
    let n_heads = args.parse_or("n-heads", 4usize)?;
    let n_layers = args.parse_or("n-layers", 2usize)?;
    let d_ff = args.parse_or("d-ff", 128usize)?;
    args.finish()?;

    let backend = quartet::kernels::backend_from_name(quartet::kernels::active().name())?;
    let cache = match &ckpt {
        // binary packed checkpoint (magic-sniffed): weights arrive
        // pre-prepared and pre-packed, so the load path runs zero prep
        // passes; the serving method is the one stored in the file
        Some(p) if PackedCheckpoint::sniff(p) => {
            let cache = PackedWeightCache::load_packed(p, &*backend)?;
            if method_flag.is_some() && method != cache.method() {
                bail!(
                    "--method {} conflicts with the packed checkpoint's stored method {} \
                     ({}); drop the flag or re-convert with `repro convert-ckpt --method`",
                    method.name(),
                    cache.method().name(),
                    p.display()
                );
            }
            cache
        }
        Some(p) => PackedWeightCache::build_model(&NativeModel::load(p)?, method, &*backend),
        None => {
            let model = match arch.as_str() {
                "mlp" => NativeModel::Mlp(MlpLm::init(
                    ModelConfig {
                        vocab,
                        d_emb,
                        d_hidden,
                        n_hidden,
                        method: TrainMethod::Quartet,
                    },
                    seed,
                )?),
                "transformer" => NativeModel::Transformer(TransformerLm::init(
                    TransformerConfig {
                        vocab,
                        d_model,
                        n_heads,
                        n_layers,
                        d_ff,
                        seq: 32,
                        method: TrainMethod::Quartet,
                    },
                    seed,
                )?),
                other => bail!("unknown --arch {other:?} (expected mlp|transformer)"),
            };
            PackedWeightCache::build_model(&model, method, &*backend)
        }
    };
    let method = cache.method();
    let vocab = cache.vocab;
    let arch_name = cache.arch_name();
    let mut eng = ServeEngine::new(cache, backend, max_batch, Sampling { temperature, seed });
    if recompute {
        eng.set_recompute(true);
    }
    eng.set_kv_options(KvServeOptions {
        page_tokens: kv_page_size,
        quant: kv_quant,
        prefill_chunk,
        max_pool_bytes: kv_pool_bytes,
        share: !no_prefix_share,
    });

    let reqs = match &trace_path {
        Some(p) => load_trace(p)?,
        None => synth_requests(&SynthOptions {
            n: n_requests,
            vocab,
            prompt_len,
            max_new_tokens: max_new,
            vary_lengths: true,
            rate,
            stop_token,
            seed,
            shared_prefix_len,
        }),
    };
    let submitted = reqs.len();
    for r in reqs {
        eng.submit(r)?;
    }
    let report = eng.run(steps_cap)?;
    println!(
        "served {}/{} requests [{arch_name} {} {} max_batch={}{}]: {} tokens, \
         {:.0} tok/s decode ({:.3}s busy / {:.3}s wall, {} steps, peak KV {} bytes)",
        report.completions.len(),
        submitted,
        method.name(),
        eng.backend_describe(),
        max_batch,
        if recompute { " recompute" } else { "" },
        report.generated_tokens,
        report.tokens_per_sec(),
        report.busy_s,
        report.wall_s,
        report.decode_steps,
        report.kv_bytes_peak
    );
    if report.kv_pages_peak > 0 {
        println!(
            "paged KV [{} page={kv_page_size}]: peak {} pages, utilization {:.2}, \
             prefix hit rate {:.2}, max concurrent {}",
            report.kv_quant,
            report.kv_pages_peak,
            report.page_utilization,
            report.prefix_hit_rate,
            report.max_concurrent
        );
    }
    let [l50, l90, l99] = report.latency_percentiles();
    let [t50, t90, t99] = report.ttft_percentiles();
    println!(
        "latency p50/p90/p99: {l50:.4}/{l90:.4}/{l99:.4} s   \
         ttft p50/p90/p99: {t50:.4}/{t90:.4}/{t99:.4} s"
    );
    if let Some(dir) = out {
        let rec = ServeRecord::from_report(
            "repro_serve",
            "continuous",
            method.name(),
            eng.backend_name(),
            max_batch,
            max_batch,
            submitted,
            &report,
        );
        let path = rec.save(&dir)?;
        println!("record: {}", path.display());
    }
    Ok(())
}

/// Convert a JSON `kind:` checkpoint into the versioned binary
/// packed-MXFP4 format (`docs/CHECKPOINT_FORMAT.md`): weight prep runs
/// ONCE here, at conversion time, and `repro serve` then loads the
/// result with zero prep passes. `--method` picks the deployed serving
/// method (defaults to the method the checkpoint was trained with).
fn cmd_convert_ckpt(args: &mut Args) -> Result<()> {
    use quartet::serve::{ckpt, ServeMethod};

    let input = PathBuf::from(args.required("checkpoint")?);
    let out = PathBuf::from(args.required("out")?);
    let method = args.get("method").map(|m| ServeMethod::parse(&m)).transpose()?;
    args.finish()?;

    let backend = quartet::kernels::active();
    let (json_bytes, packed_bytes) = ckpt::convert(&input, &out, method, backend)?;
    println!(
        "converted {} ({json_bytes} bytes JSON) -> {} ({packed_bytes} bytes packed, \
         {:.2}x smaller); serve it with `repro serve --checkpoint {}`",
        input.display(),
        out.display(),
        json_bytes as f64 / (packed_bytes as f64).max(1.0),
        out.display()
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_serve_xla(args: &mut Args, artifact: &str) -> Result<()> {
    let root = artifacts_root(args);
    let n_requests = args.parse_or("requests", 64usize)?;
    let seed = args.parse_or("seed", 0u64)?;
    args.finish()?;

    let engine = Engine::cpu()?;
    let art = engine.load_named(&root, artifact)?;
    let mut eng = quartet::serve::PrefillEngine::new(&art, seed)?;
    let mut rng = quartet::util::rng::Rng::new(seed);
    let vocab = art.manifest.model.vocab;
    for id in 0..n_requests as u64 {
        let tokens: Vec<i32> = (0..eng.seq).map(|_| rng.below(vocab) as i32).collect();
        eng.submit(quartet::serve::Request { id, tokens });
    }
    let (done, wall, tps) = eng.drain()?;
    println!(
        "served {} requests (batch={}, seq={}): {:.3}s wall, {:.0} prefill tokens/s",
        done.len(),
        eng.batch,
        eng.seq,
        wall,
        tps
    );
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_serve_xla(_args: &mut Args, _artifact: &str) -> Result<()> {
    no_xla("serve --artifact (the native `repro serve` needs no XLA)")
}

fn cmd_regions(args: &mut Args) -> Result<()> {
    let steps = args.parse_or("steps", 24usize)?;
    args.finish()?;
    use quartet::scaling::law::PAPER_LAW;
    use quartet::scaling::regions::{region_grid, render_ascii, Precision};
    use quartet::scaling::speedup::{Speedups, PAPER_MEASURED_FP4};

    for (title, fp4_bwd) in [("Fig 1(b): FP8 backward", false), ("Fig 1(c): FP4 backward", true)] {
        let cands = vec![
            Precision {
                label: "8 (fp8 fwd)".into(),
                eff_n: 0.93,
                eff_d: if fp4_bwd { 0.94 } else { 0.99 },
                speedups: Speedups { forward: 1.0, backward: if fp4_bwd { 1.6 } else { 1.0 } },
            },
            Precision {
                label: "4 (fp4 fwd)".into(),
                eff_n: 0.64,
                eff_d: if fp4_bwd { 0.94 } else { 0.99 },
                speedups: if fp4_bwd {
                    PAPER_MEASURED_FP4
                } else {
                    Speedups { forward: 2.4, backward: 1.0 }
                },
            },
        ];
        let grid = region_grid(&PAPER_LAW, &cands, (30e6, 100e9), (10.0, 10_000.0), steps);
        println!("\n{title} (rows: model size desc, cols: D/N 10→10k)");
        print!("{}", render_ascii(&grid, steps));
    }
    Ok(())
}

fn cmd_table2(args: &mut Args) -> Result<()> {
    let trials = args.parse_or("trials", 400usize)?;
    args.finish()?;
    use quartet::analysis::alignment::{gaussian_mse, pma_misalignment};
    use quartet::quant::methods::table2_rows;
    use quartet::util::rng::Rng;

    let mut rng = Rng::new(0x7AB2u64);
    println!("backend: {}", quartet::kernels::active().name());
    println!("{:<20} {:>12} {:>16}", "method", "MSE", "misalignment");
    for q in table2_rows() {
        let mse = gaussian_mse(q.as_ref(), 256, 128, &mut rng);
        let mis = pma_misalignment(q.as_ref(), 16, 64, trials, &mut rng);
        println!("{:<20} {:>12.4e} {:>16.3e}", q.name(), mse, mis);
    }
    Ok(())
}

/// Quick all-backends kernel race on one GEMM shape — the smallest
/// end-to-end check that the backend layer delivers (Fig 3's CPU story).
fn cmd_kernels(args: &mut Args) -> Result<()> {
    let m = args.parse_or("m", 256usize)?;
    let n = args.parse_or("n", 11008usize)?;
    let k = args.parse_or("k", 4096usize)?;
    args.finish()?;
    use quartet::quant::mxfp4::QuantMode;
    use quartet::util::bench::Bencher;
    use quartet::util::rng::Rng;

    anyhow::ensure!(k % 32 == 0, "--k must be a multiple of 32");
    let b = Bencher::from_env();
    let mut rng = Rng::new(0xBEEF);
    let x = rng.gaussian_vec(m * k, 1.0);
    let w = rng.gaussian_vec(n * k, 0.3);

    println!("GEMM shape m={m} n={n} k={k}");
    let mut scalar_median = 0.0f64;
    for name in ["scalar", "parallel", "simd", "parallel+simd"] {
        let be = quartet::kernels::backend_from_name(name)?;
        let tx = be.quantize_mxfp4(&x, m, k, QuantMode::Rtn, &mut Rng::new(1));
        let tw = be.quantize_mxfp4(&w, n, k, QuantMode::Rtn, &mut Rng::new(2));
        let gemm = b.bench("gemm", || be.gemm_mxfp4(&tx, &tw));
        let quant = b.bench("quant", || {
            be.quantize_mxfp4(&x, m, k, QuantMode::Rtn, &mut Rng::new(1))
        });
        let med = gemm.median();
        print!(
            "  {:<20} mxfp4 gemm {:>9.2} ms   quantize {:>9.2} ms",
            be.describe(),
            med * 1e3,
            quant.median() * 1e3
        );
        if name == "scalar" {
            scalar_median = med;
            println!();
        } else if med > 0.0 && scalar_median > 0.0 {
            println!("   ({:.2}x vs scalar)", scalar_median / med);
        } else {
            println!();
        }
    }
    Ok(())
}

/// Perf-regression gate over the bench-record JSON the figure benches
/// emit: every record under `--dir` (recursively) is validated against
/// the run/serve schemas and its throughput/latency compared to the
/// committed floors in `tests/data/bench_baselines.json`. Nonzero exit on
/// any violation — CI runs this after the fig1/fig6/fig7/fig8 smokes so a
/// silent order-of-magnitude slowdown fails the build instead of
/// shipping.
fn cmd_check_records(args: &mut Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("dir", "runs"));
    let baselines = args.get("baselines").map(PathBuf::from);
    args.finish()?;
    let report = quartet::coordinator::check::check_records(&dir, baselines.as_deref())?;
    println!("{}", report.summary());
    if report.violations.is_empty() {
        Ok(())
    } else {
        for v in &report.violations {
            eprintln!("FAIL {v}");
        }
        bail!(
            "{} violation(s) across {} record(s) — see FAIL lines above",
            report.violations.len(),
            report.checked
        )
    }
}
