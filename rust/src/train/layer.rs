//! Quantized linear layers: Quartet Algorithm 1's forward/backward on the
//! [`crate::kernels::Backend`] layer.
//!
//! Forward (quartet): `y = Q(H·x) · Q(H·w)ᵀ` through the packed
//! block-scaled GEMM — the per-group Hadamard cancels in the contraction,
//! so `y ≈ x·wᵀ` while both operands are genuine MXFP4 tensors.
//!
//! Backward (quartet): the incoming gradient is quantized with the
//! randomized-Hadamard + SR(3/4·x) scheme (unbiased end to end, the
//! `QuartetSr` path), the two gradient GEMMs run against the *quantized*
//! forward operands (straight-through), and the QuEST trust masks gate
//! the Hadamard-space gradients through the backend's fused masked GEMM
//! before rotating back.

use crate::kernels::Backend;
use crate::quant::fp8::mxfp8_rtn;
use crate::quant::methods::quartet_sr_dequant;
use crate::quant::mxfp4::{QuantMode, MX_GROUP};
use crate::train::TrainMethod;
use crate::util::rng::Rng;

/// One weight matrix `[d_out, d_in]` (row-major), master copy in f32 —
/// quantization happens on the way into every GEMM, QAT-style.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub d_out: usize,
    pub d_in: usize,
    pub w: Vec<f32>,
}

/// Forward-pass residue the backward consumes.
pub struct LinearCache {
    /// layer input in original space, `[rows, d_in]` (ReLU gate upstream,
    /// f32 weight-gradient contraction)
    pub x: Vec<f32>,
    /// quantize-dequantized input as the forward GEMM consumed it
    /// (Hadamard space for quartet/rtn, original space for mxfp8)
    pub xq: Option<Vec<f32>>,
    /// quantize-dequantized weight, same space as `xq`
    pub wq: Option<Vec<f32>>,
    /// QuEST trust mask over the (Hadamard-space) input, bit per element
    pub mask_x: Option<Vec<u64>>,
    /// QuEST trust mask over the (Hadamard-space) weight
    pub mask_w: Option<Vec<u64>>,
}

impl QuantLinear {
    /// 1/√d_in Gaussian init (activation variance stationary with depth).
    pub fn init(d_out: usize, d_in: usize, rng: &mut Rng) -> QuantLinear {
        let scale = 1.0 / (d_in as f32).sqrt();
        QuantLinear { d_out, d_in, w: rng.gaussian_vec(d_out * d_in, scale) }
    }

    pub fn from_weights(d_out: usize, d_in: usize, w: Vec<f32>) -> QuantLinear {
        assert_eq!(w.len(), d_out * d_in, "weight shape mismatch");
        QuantLinear { d_out, d_in, w }
    }

    /// `y = x·wᵀ` under the method's forward precision; returns the
    /// `[rows, d_out]` output and the backward cache.
    pub fn forward(
        &self,
        x: &[f32],
        rows: usize,
        method: TrainMethod,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (Vec<f32>, LinearCache) {
        forward_with(&self.w, self.d_out, self.d_in, x, rows, method, be, rng)
    }

    /// Gradient step: from `dy [rows, d_out]` produce
    /// `(dx [rows, d_in], dw [d_out, d_in])` under the method's backward
    /// precision (straight-through estimator through the forward
    /// quantizers; quartet additionally gates by the trust masks).
    pub fn backward(
        &self,
        dy: &[f32],
        cache: &LinearCache,
        rows: usize,
        method: TrainMethod,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        backward_with(&self.w, self.d_out, self.d_in, dy, cache, rows, method, be, rng)
    }
}

/// Method-dispatch forward on a *borrowed* `[d_out, d_in]` weight matrix
/// — shared by [`QuantLinear`] and the transformer's tied vocab head,
/// whose weight IS the f32 embedding table (quantized on the way into
/// the GEMM, QAT-style, while the master stays shared and f32).
#[allow(clippy::too_many_arguments)]
pub fn forward_with(
    w: &[f32],
    d_out: usize,
    d_in: usize,
    x: &[f32],
    rows: usize,
    method: TrainMethod,
    be: &dyn Backend,
    rng: &mut Rng,
) -> (Vec<f32>, LinearCache) {
    assert_eq!(x.len(), rows * d_in);
    assert_eq!(w.len(), d_out * d_in);
    match method {
        TrainMethod::F32 => {
            let y = be.gemm_f32(x, w, rows, d_out, d_in);
            (y, LinearCache { x: x.to_vec(), xq: None, wq: None, mask_x: None, mask_w: None })
        }
        TrainMethod::Mxfp8 => {
            let xq = mxfp8_rtn(x);
            let wq = mxfp8_rtn(w);
            let y = be.gemm_f32(&xq, &wq, rows, d_out, d_in);
            (y, LinearCache {
                x: x.to_vec(),
                xq: Some(xq),
                wq: Some(wq),
                mask_x: None,
                mask_w: None,
            })
        }
        TrainMethod::Quartet => {
            let mut xh = x.to_vec();
            be.block_hadamard(&mut xh, MX_GROUP);
            let xt = be.quantize_mxfp4(&xh, rows, d_in, QuantMode::Quest, rng);
            let mut wh = w.to_vec();
            be.block_hadamard(&mut wh, MX_GROUP);
            let wt = be.quantize_mxfp4(&wh, d_out, d_in, QuantMode::Quest, rng);
            let y = be.gemm_mxfp4(&xt, &wt);
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(xt.dequantize()),
                wq: Some(wt.dequantize()),
                mask_x: xt.mask,
                mask_w: wt.mask,
            };
            (y, cache)
        }
        TrainMethod::Rtn => {
            // naive MXFP4: no rotation anywhere — absmax RTN straight
            // on the raw tensors. Heavy-tailed activations/gradients
            // are exactly what this baseline cannot survive (Table 2's
            // misalignment story), which is why it loses the ordering.
            let xt = be.quantize_mxfp4(x, rows, d_in, QuantMode::Rtn, rng);
            let wt = be.quantize_mxfp4(w, d_out, d_in, QuantMode::Rtn, rng);
            let y = be.gemm_mxfp4(&xt, &wt);
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(xt.dequantize()),
                wq: Some(wt.dequantize()),
                mask_x: None,
                mask_w: None,
            };
            (y, cache)
        }
    }
}

/// Backward twin of [`forward_with`]; see [`QuantLinear::backward`].
#[allow(clippy::too_many_arguments)]
pub fn backward_with(
    w: &[f32],
    d_out: usize,
    d_in: usize,
    dy: &[f32],
    cache: &LinearCache,
    rows: usize,
    method: TrainMethod,
    be: &dyn Backend,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(dy.len(), rows * d_out);
    match method {
        TrainMethod::F32 => {
            let wt = transpose(w, d_out, d_in);
            let dx = be.gemm_f32(dy, &wt, rows, d_in, d_out);
            let dyt = transpose(dy, rows, d_out);
            let xt = transpose(&cache.x, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Mxfp8 => {
            let dyq = mxfp8_rtn(dy);
            let wq = cache.wq.as_ref().expect("mxfp8 cache");
            let xq = cache.xq.as_ref().expect("mxfp8 cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(&dyq, &wt, rows, d_in, d_out);
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Quartet => {
            // Algorithm 1 backward: unbiased SR(3/4·x) gradient
            // quantization, both gradient GEMMs against the quantized
            // forward operands — in Hadamard space, where the trust
            // masks live — then rotate back.
            let dyq = quartet_sr_dequant(be, dy, rows, d_out, rng);
            let wq = cache.wq.as_ref().expect("quartet cache");
            let xq = cache.xq.as_ref().expect("quartet cache");
            // dL/d(Hx) = mask_x ⊙ (dyq · Q(Hw)); then dx = H·dL/d(Hx)
            let wt = transpose(wq, d_out, d_in);
            let mut dxh =
                be.gemm_f32_masked(&dyq, &wt, rows, d_in, d_out, cache.mask_x.as_deref());
            be.block_hadamard_inv(&mut dxh, MX_GROUP);
            // dL/d(Hw) = mask_w ⊙ (dyqᵀ · Q(Hx)); then dw = H·dL/d(Hw)
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let mut dwh =
                be.gemm_f32_masked(&dyt, &xt, d_out, d_in, rows, cache.mask_w.as_deref());
            be.block_hadamard_inv(&mut dwh, MX_GROUP);
            (dxh, dwh)
        }
        TrainMethod::Rtn => {
            // naive backward: deterministic RTN on the raw gradient
            // (biased — the bulk of a softmax gradient's small entries
            // rounds to zero against the group absmax), straight
            // GEMMs, no masks, no rotation
            let dyq = rtn_dequant(be, dy, rows, d_out, rng);
            let wq = cache.wq.as_ref().expect("rtn cache");
            let xq = cache.xq.as_ref().expect("rtn cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(&dyq, &wt, rows, d_in, d_out);
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
    }
}

/// The naive baseline's gradient quantizer: plain absmax RTN quant-dequant,
/// no rotation (biased — small gradient coordinates round to zero against
/// the group absmax, and without the Hadamard there is nothing to spread
/// the heavy tail).
pub fn rtn_dequant(
    be: &dyn Backend,
    x: &[f32],
    rows: usize,
    cols: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    be.quantize_mxfp4(x, rows, cols, QuantMode::Rtn, rng).dequantize()
}

/// Row-major `[rows, cols]` → `[cols, rows]` (the gradient GEMMs contract
/// over rows; `Backend::gemm_f32*` contracts over the last axis).
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let x = rng.gaussian_vec(6 * 4, 1.0);
        let t = transpose(&x, 6, 4);
        assert_eq!(transpose(&t, 4, 6), x);
        // t[c, r] == x[r, c]
        assert_eq!(t[2], x[2 * 4]);
        assert_eq!(t[3 * 6 + 5], x[5 * 4 + 3]);
    }

    /// f32 backward must match the numerical gradient of the quadratic
    /// probe L = ½‖y‖² (whose dL/dy = y) — pins the transpose plumbing.
    #[test]
    fn f32_backward_matches_finite_difference() {
        let be = ScalarBackend;
        let mut rng = Rng::new(2);
        let (rows, d_in, d_out) = (4, 32, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let x = rng.gaussian_vec(rows * d_in, 1.0);
        let loss = |layer: &QuantLinear, x: &[f32]| -> f64 {
            let (y, _) = layer.forward(x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
            y.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
        };
        let (y, cache) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (dx, dw) = layer.backward(&y, &cache, rows, TrainMethod::F32, &be, &mut Rng::new(0));

        // the probe loss is exactly quadratic, so the central difference
        // is exact up to f32 rounding — a generous eps keeps the rounding
        // noise far below the tolerance
        let eps = 5e-2f32;
        for &idx in &[0usize, 7, 63, rows * d_in - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[idx] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dx[{idx}]: {num} vs {}",
                dx[idx]
            );
        }
        for &idx in &[0usize, 33, d_out * d_in - 1] {
            let mut lp = layer.clone();
            lp.w[idx] += eps;
            let mut lm = layer.clone();
            lm.w[idx] -= eps;
            let num = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
            assert!(
                (num - dw[idx] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dw[{idx}]: {num} vs {}",
                dw[idx]
            );
        }
    }

    #[test]
    fn quartet_forward_approximates_f32() {
        let be = ScalarBackend;
        let mut rng = Rng::new(4);
        let (rows, d_in, d_out) = (8, 64, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let x = rng.gaussian_vec(rows * d_in, 1.0);
        let (exact, _) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (q, _) = layer.forward(&x, rows, TrainMethod::Quartet, &be, &mut Rng::new(0));
        let scale = (exact.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        let err = (exact
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        assert!(err < 0.35 * scale, "relative fp4 error {err} vs rms {scale}");
    }

    #[test]
    fn quartet_backward_carries_trust_mask() {
        // QuEST forward must hand its trust mask to the backward, and the
        // masked gradient path must stay finite under extreme inputs.
        let be = ScalarBackend;
        let mut rng = Rng::new(5);
        let (rows, d_in, d_out) = (1, 32, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let mut x = rng.gaussian_vec(rows * d_in, 1.0);
        x[3] = 1000.0;
        let (y, cache) = layer.forward(&x, rows, TrainMethod::Quartet, &be, &mut Rng::new(6));
        assert!(cache.mask_x.is_some(), "quest forward must carry a mask");
        let dy: Vec<f32> = y.iter().map(|_| 1.0).collect();
        let (dx, _) = layer.backward(&dy, &cache, rows, TrainMethod::Quartet, &be, &mut Rng::new(7));
        assert!(dx.iter().all(|v| v.is_finite()));
    }
}
