//! Quantized linear layers: Quartet Algorithm 1's forward/backward on the
//! [`crate::kernels::Backend`] layer.
//!
//! Forward (quartet): `y = Q(H·x) · Q(H·w)ᵀ` through the packed
//! block-scaled GEMM — the per-group Hadamard cancels in the contraction,
//! so `y ≈ x·wᵀ` while both operands are genuine MXFP4 tensors.
//!
//! Backward (quartet): the incoming gradient is quantized with the
//! randomized-Hadamard + SR(3/4·x) scheme (unbiased end to end, the
//! `QuartetSr` path), the two gradient GEMMs run against the *quantized*
//! forward operands (straight-through), and the QuEST trust masks gate
//! the Hadamard-space gradients through the backend's fused masked GEMM
//! before rotating back.

use crate::kernels::Backend;
use crate::quant::format::{MXFP4, NVFP4};
use crate::quant::fp8::mxfp8_rtn;
use crate::quant::methods::{nvfp4_sr_dequant, quartet_sr_dequant};
use crate::quant::mxfp4::QuantMode;
use crate::train::TrainMethod;
use crate::util::rng::Rng;

/// fp4-clamp: activation outliers are clamped at this |x| quantile and the
/// clipped residual is compensated exactly through a sparse f32 GEMM
/// (the OCC half of "Optimizing LLM Training Using FP4 Quantization").
pub const OCC_QUANTILE: f32 = 0.99;

/// fp4-clamp: exponent of the power surrogate whose derivative replaces
/// STE's unit derivative on the weight gradient (the DGE half).
pub const DGE_K: f32 = 5.0;

/// Cap on the DGE derivative so near-zero weights cannot blow up their
/// gradient (the surrogate derivative diverges at |w| → 0).
pub const DGE_CAP: f32 = 3.0;

/// One weight matrix `[d_out, d_in]` (row-major), master copy in f32 —
/// quantization happens on the way into every GEMM, QAT-style.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub d_out: usize,
    pub d_in: usize,
    pub w: Vec<f32>,
}

/// Forward-pass residue the backward consumes.
pub struct LinearCache {
    /// layer input in original space, `[rows, d_in]` (ReLU gate upstream,
    /// f32 weight-gradient contraction)
    pub x: Vec<f32>,
    /// quantize-dequantized input as the forward GEMM consumed it
    /// (Hadamard space for quartet/rtn, original space for mxfp8)
    pub xq: Option<Vec<f32>>,
    /// quantize-dequantized weight, same space as `xq`
    pub wq: Option<Vec<f32>>,
    /// QuEST trust mask over the (Hadamard-space) input, bit per element
    pub mask_x: Option<Vec<u64>>,
    /// QuEST trust mask over the (Hadamard-space) weight
    pub mask_w: Option<Vec<u64>>,
}

impl QuantLinear {
    /// 1/√d_in Gaussian init (activation variance stationary with depth).
    pub fn init(d_out: usize, d_in: usize, rng: &mut Rng) -> QuantLinear {
        let scale = 1.0 / (d_in as f32).sqrt();
        QuantLinear { d_out, d_in, w: rng.gaussian_vec(d_out * d_in, scale) }
    }

    pub fn from_weights(d_out: usize, d_in: usize, w: Vec<f32>) -> QuantLinear {
        assert_eq!(w.len(), d_out * d_in, "weight shape mismatch");
        QuantLinear { d_out, d_in, w }
    }

    /// `y = x·wᵀ` under the method's forward precision; returns the
    /// `[rows, d_out]` output and the backward cache.
    pub fn forward(
        &self,
        x: &[f32],
        rows: usize,
        method: TrainMethod,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (Vec<f32>, LinearCache) {
        forward_with(&self.w, self.d_out, self.d_in, x, rows, method, be, rng)
    }

    /// Gradient step: from `dy [rows, d_out]` produce
    /// `(dx [rows, d_in], dw [d_out, d_in])` under the method's backward
    /// precision (straight-through estimator through the forward
    /// quantizers; quartet additionally gates by the trust masks).
    pub fn backward(
        &self,
        dy: &[f32],
        cache: &LinearCache,
        rows: usize,
        method: TrainMethod,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        backward_with(&self.w, self.d_out, self.d_in, dy, cache, rows, method, be, rng)
    }
}

/// Method-dispatch forward on a *borrowed* `[d_out, d_in]` weight matrix
/// — shared by [`QuantLinear`] and the transformer's tied vocab head,
/// whose weight IS the f32 embedding table (quantized on the way into
/// the GEMM, QAT-style, while the master stays shared and f32).
#[allow(clippy::too_many_arguments)]
pub fn forward_with(
    w: &[f32],
    d_out: usize,
    d_in: usize,
    x: &[f32],
    rows: usize,
    method: TrainMethod,
    be: &dyn Backend,
    rng: &mut Rng,
) -> (Vec<f32>, LinearCache) {
    assert_eq!(x.len(), rows * d_in);
    assert_eq!(w.len(), d_out * d_in);
    match method {
        TrainMethod::F32 => {
            let y = be.gemm_f32(x, w, rows, d_out, d_in);
            (y, LinearCache { x: x.to_vec(), xq: None, wq: None, mask_x: None, mask_w: None })
        }
        TrainMethod::Mxfp8 => {
            let xq = mxfp8_rtn(x);
            let wq = mxfp8_rtn(w);
            let y = be.gemm_f32(&xq, &wq, rows, d_out, d_in);
            (y, LinearCache {
                x: x.to_vec(),
                xq: Some(xq),
                wq: Some(wq),
                mask_x: None,
                mask_w: None,
            })
        }
        TrainMethod::Quartet => {
            let mut xh = x.to_vec();
            be.block_hadamard(&mut xh, MXFP4.group);
            let xt = be.quantize_mxfp4(&xh, rows, d_in, QuantMode::Quest, rng);
            let mut wh = w.to_vec();
            be.block_hadamard(&mut wh, MXFP4.group);
            let wt = be.quantize_mxfp4(&wh, d_out, d_in, QuantMode::Quest, rng);
            let y = be.gemm_mxfp4(&xt, &wt);
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(xt.dequantize()),
                wq: Some(wt.dequantize()),
                mask_x: xt.mask,
                mask_w: wt.mask,
            };
            (y, cache)
        }
        TrainMethod::Rtn => {
            // naive MXFP4: no rotation anywhere — absmax RTN straight
            // on the raw tensors. Heavy-tailed activations/gradients
            // are exactly what this baseline cannot survive (Table 2's
            // misalignment story), which is why it loses the ordering.
            let xt = be.quantize_mxfp4(x, rows, d_in, QuantMode::Rtn, rng);
            let wt = be.quantize_mxfp4(w, d_out, d_in, QuantMode::Rtn, rng);
            let y = be.gemm_mxfp4(&xt, &wt);
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(xt.dequantize()),
                wq: Some(wt.dequantize()),
                mask_x: None,
                mask_w: None,
            };
            (y, cache)
        }
        TrainMethod::Nvfp4 => {
            // NVFP4 forward: RTN on the 16-group / E4M3-scale / two-level
            // descriptor, straight on the raw tensors — the fractional
            // scales recover most of what MXFP4's power-of-two scales
            // waste, without needing a rotation to survive
            let xt = be.quantize_group(x, rows, d_in, &NVFP4, QuantMode::Rtn, rng);
            let wt = be.quantize_group(w, d_out, d_in, &NVFP4, QuantMode::Rtn, rng);
            let y = be.gemm_group(&xt, &wt);
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(be.decode_group(&xt)),
                wq: Some(be.decode_group(&wt)),
                mask_x: None,
                mask_w: None,
            };
            (y, cache)
        }
        TrainMethod::Fp4Clamp => {
            // OCC: clamp activations at the |x| quantile, quantize the
            // clamped bulk to MXFP4, and compensate the clipped residual
            // *exactly* through a sparse f32 GEMM — outliers never touch
            // the 4-bit grid, everything else does
            let tau = abs_quantile(x, OCC_QUANTILE);
            let mut xc = x.to_vec();
            let mut delta = vec![0.0f32; x.len()];
            let mut outliers = false;
            for (c, d) in xc.iter_mut().zip(delta.iter_mut()) {
                let clamped = c.clamp(-tau, tau);
                *d = *c - clamped;
                if *d != 0.0 {
                    outliers = true;
                }
                *c = clamped;
            }
            let xt = be.quantize_group(&xc, rows, d_in, &MXFP4, QuantMode::Rtn, rng);
            let wt = be.quantize_group(w, d_out, d_in, &MXFP4, QuantMode::Rtn, rng);
            let mut y = be.gemm_group(&xt, &wt);
            let wq = be.decode_group(&wt);
            if outliers {
                let comp = be.gemm_f32(&delta, &wq, rows, d_out, d_in);
                for (a, b) in y.iter_mut().zip(&comp) {
                    *a += *b;
                }
            }
            // the backward sees the *effective* forward input
            // Q(clamp(x)) + Δ, so the compensation flows through dw too
            let mut xq = be.decode_group(&xt);
            for (a, b) in xq.iter_mut().zip(&delta) {
                *a += *b;
            }
            let cache = LinearCache {
                x: x.to_vec(),
                xq: Some(xq),
                wq: Some(wq),
                mask_x: None,
                mask_w: None,
            };
            (y, cache)
        }
    }
}

/// Backward twin of [`forward_with`]; see [`QuantLinear::backward`].
#[allow(clippy::too_many_arguments)]
pub fn backward_with(
    w: &[f32],
    d_out: usize,
    d_in: usize,
    dy: &[f32],
    cache: &LinearCache,
    rows: usize,
    method: TrainMethod,
    be: &dyn Backend,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(dy.len(), rows * d_out);
    match method {
        TrainMethod::F32 => {
            let wt = transpose(w, d_out, d_in);
            let dx = be.gemm_f32(dy, &wt, rows, d_in, d_out);
            let dyt = transpose(dy, rows, d_out);
            let xt = transpose(&cache.x, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Mxfp8 => {
            let dyq = mxfp8_rtn(dy);
            let wq = cache.wq.as_ref().expect("mxfp8 cache");
            let xq = cache.xq.as_ref().expect("mxfp8 cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(&dyq, &wt, rows, d_in, d_out);
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Quartet => {
            // Algorithm 1 backward: unbiased SR(3/4·x) gradient
            // quantization, both gradient GEMMs against the quantized
            // forward operands — in Hadamard space, where the trust
            // masks live — then rotate back.
            let dyq = quartet_sr_dequant(be, dy, rows, d_out, rng);
            let wq = cache.wq.as_ref().expect("quartet cache");
            let xq = cache.xq.as_ref().expect("quartet cache");
            // dL/d(Hx) = mask_x ⊙ (dyq · Q(Hw)); then dx = H·dL/d(Hx)
            let wt = transpose(wq, d_out, d_in);
            let mut dxh =
                be.gemm_f32_masked(&dyq, &wt, rows, d_in, d_out, cache.mask_x.as_deref());
            be.block_hadamard_inv(&mut dxh, MXFP4.group);
            // dL/d(Hw) = mask_w ⊙ (dyqᵀ · Q(Hx)); then dw = H·dL/d(Hw)
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let mut dwh =
                be.gemm_f32_masked(&dyt, &xt, d_out, d_in, rows, cache.mask_w.as_deref());
            be.block_hadamard_inv(&mut dwh, MXFP4.group);
            (dxh, dwh)
        }
        TrainMethod::Rtn => {
            // naive backward: deterministic RTN on the raw gradient
            // (biased — the bulk of a softmax gradient's small entries
            // rounds to zero against the group absmax), straight
            // GEMMs, no masks, no rotation
            let dyq = rtn_dequant(be, dy, rows, d_out, rng);
            let wq = cache.wq.as_ref().expect("rtn cache");
            let xq = cache.xq.as_ref().expect("rtn cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(&dyq, &wt, rows, d_in, d_out);
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Nvfp4 => {
            // NVFP4 backward: Quartet's unbiased structure on the NVFP4
            // descriptor (randomized group-16 Hadamard + SR(3/4·x) + 4/3),
            // then straight-through GEMMs against the quantized forward
            // operands — no trust masks on this recipe
            let dyq = nvfp4_sr_dequant(be, dy, rows, d_out, rng);
            let wq = cache.wq.as_ref().expect("nvfp4 cache");
            let xq = cache.xq.as_ref().expect("nvfp4 cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(&dyq, &wt, rows, d_in, d_out);
            let dyt = transpose(&dyq, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            (dx, dw)
        }
        TrainMethod::Fp4Clamp => {
            // the recipe keeps gradients in high precision (only the
            // forward GEMM is 4-bit); DGE replaces STE's unit derivative
            // on the weight gradient with the capped derivative of a
            // power surrogate of the quantizer, so weights sitting in the
            // flat low-magnitude region of the E2M1 grid keep moving
            let wq = cache.wq.as_ref().expect("fp4-clamp cache");
            let xq = cache.xq.as_ref().expect("fp4-clamp cache");
            let wt = transpose(wq, d_out, d_in);
            let dx = be.gemm_f32(dy, &wt, rows, d_in, d_out);
            let dyt = transpose(dy, rows, d_out);
            let xt = transpose(xq, rows, d_in);
            let mut dw = be.gemm_f32(&dyt, &xt, d_out, d_in, rows);
            apply_dge(&mut dw, w, d_out, d_in);
            (dx, dw)
        }
    }
}

/// The |x| quantile used by fp4-clamp's OCC step (q in [0, 1]).
fn abs_quantile(x: &[f32], q: f32) -> f32 {
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let idx = ((mags.len() - 1) as f32 * q) as usize;
    let (_, tau, _) = mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    *tau
}

/// DGE: scale each weight-gradient element by the derivative of the power
/// surrogate `f(u) = u^(1/k)` of the normalized magnitude
/// `u = |w| / group_absmax` — steep (capped at [`DGE_CAP`]) where the
/// E2M1 grid is flat near zero, shallow near the group max, mean ≈ 1 over
/// a uniform magnitude distribution so the overall gradient scale is
/// preserved. Group geometry follows the forward quantizer (MXFP4).
pub fn apply_dge(dw: &mut [f32], w: &[f32], d_out: usize, d_in: usize) {
    assert_eq!(dw.len(), d_out * d_in);
    assert_eq!(w.len(), d_out * d_in);
    let g = MXFP4.group;
    for r in 0..d_out {
        for gi in 0..d_in / g {
            let base = r * d_in + gi * g;
            let grp = &w[base..base + g];
            let amax = grp.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if amax == 0.0 {
                continue;
            }
            for i in 0..g {
                let u = (grp[i].abs() / amax).min(1.0);
                let factor =
                    ((1.0 / DGE_K) * u.max(1e-12).powf(1.0 / DGE_K - 1.0)).min(DGE_CAP);
                dw[base + i] *= factor;
            }
        }
    }
}

/// The naive baseline's gradient quantizer: plain absmax RTN quant-dequant,
/// no rotation (biased — small gradient coordinates round to zero against
/// the group absmax, and without the Hadamard there is nothing to spread
/// the heavy tail).
pub fn rtn_dequant(
    be: &dyn Backend,
    x: &[f32],
    rows: usize,
    cols: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    be.quantize_mxfp4(x, rows, cols, QuantMode::Rtn, rng).dequantize()
}

/// Row-major `[rows, cols]` → `[cols, rows]` (the gradient GEMMs contract
/// over rows; `Backend::gemm_f32*` contracts over the last axis).
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let x = rng.gaussian_vec(6 * 4, 1.0);
        let t = transpose(&x, 6, 4);
        assert_eq!(transpose(&t, 4, 6), x);
        // t[c, r] == x[r, c]
        assert_eq!(t[2], x[2 * 4]);
        assert_eq!(t[3 * 6 + 5], x[5 * 4 + 3]);
    }

    /// f32 backward must match the numerical gradient of the quadratic
    /// probe L = ½‖y‖² (whose dL/dy = y) — pins the transpose plumbing.
    #[test]
    fn f32_backward_matches_finite_difference() {
        let be = ScalarBackend;
        let mut rng = Rng::new(2);
        let (rows, d_in, d_out) = (4, 32, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let x = rng.gaussian_vec(rows * d_in, 1.0);
        let loss = |layer: &QuantLinear, x: &[f32]| -> f64 {
            let (y, _) = layer.forward(x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
            y.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
        };
        let (y, cache) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (dx, dw) = layer.backward(&y, &cache, rows, TrainMethod::F32, &be, &mut Rng::new(0));

        // the probe loss is exactly quadratic, so the central difference
        // is exact up to f32 rounding — a generous eps keeps the rounding
        // noise far below the tolerance
        let eps = 5e-2f32;
        for &idx in &[0usize, 7, 63, rows * d_in - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[idx] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dx[{idx}]: {num} vs {}",
                dx[idx]
            );
        }
        for &idx in &[0usize, 33, d_out * d_in - 1] {
            let mut lp = layer.clone();
            lp.w[idx] += eps;
            let mut lm = layer.clone();
            lm.w[idx] -= eps;
            let num = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64);
            assert!(
                (num - dw[idx] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dw[{idx}]: {num} vs {}",
                dw[idx]
            );
        }
    }

    #[test]
    fn quartet_forward_approximates_f32() {
        let be = ScalarBackend;
        let mut rng = Rng::new(4);
        let (rows, d_in, d_out) = (8, 64, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let x = rng.gaussian_vec(rows * d_in, 1.0);
        let (exact, _) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (q, _) = layer.forward(&x, rows, TrainMethod::Quartet, &be, &mut Rng::new(0));
        let scale = (exact.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        let err = (exact
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        assert!(err < 0.35 * scale, "relative fp4 error {err} vs rms {scale}");
    }

    #[test]
    fn nvfp4_forward_approximates_f32() {
        let be = ScalarBackend;
        let mut rng = Rng::new(14);
        let (rows, d_in, d_out) = (8, 64, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let x = rng.gaussian_vec(rows * d_in, 1.0);
        let (exact, _) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (q, cache) = layer.forward(&x, rows, TrainMethod::Nvfp4, &be, &mut Rng::new(0));
        assert!(cache.xq.is_some() && cache.wq.is_some());
        let scale = (exact.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        let err = (exact
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / exact.len() as f64)
            .sqrt();
        assert!(err < 0.35 * scale, "relative nvfp4 error {err} vs rms {scale}");
        let dy: Vec<f32> = q.iter().map(|_| 0.5).collect();
        let (dx, dw) =
            layer.backward(&dy, &cache, rows, TrainMethod::Nvfp4, &be, &mut Rng::new(1));
        assert!(dx.iter().chain(dw.iter()).all(|v| v.is_finite()));
    }

    #[test]
    fn fp4_clamp_compensation_beats_plain_rtn_under_outliers() {
        // one giant activation outlier wrecks the whole RTN group (the
        // absmax scale flushes everything else to zero); OCC clamps it,
        // quantizes the bulk on a sane scale, and adds the outlier back
        // exactly — so fp4-clamp must track f32 far better than rtn here
        let be = ScalarBackend;
        let mut rng = Rng::new(15);
        let (rows, d_in, d_out) = (4, 64, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let mut x = rng.gaussian_vec(rows * d_in, 1.0);
        x[10] = 500.0;
        x[70] = -350.0;
        let (exact, _) = layer.forward(&x, rows, TrainMethod::F32, &be, &mut Rng::new(0));
        let (clamped, _) =
            layer.forward(&x, rows, TrainMethod::Fp4Clamp, &be, &mut Rng::new(0));
        let (naive, _) = layer.forward(&x, rows, TrainMethod::Rtn, &be, &mut Rng::new(0));
        let err = |y: &[f32]| {
            exact
                .iter()
                .zip(y)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let (ec, en) = (err(&clamped), err(&naive));
        assert!(ec < en / 4.0, "fp4-clamp err {ec} vs rtn err {en}");
    }

    #[test]
    fn dge_preserves_gradient_scale_and_caps() {
        let mut rng = Rng::new(16);
        let (d_out, d_in) = (8, 64);
        let w = rng.gaussian_vec(d_out * d_in, 1.0);
        let mut dw = vec![1.0f32; d_out * d_in];
        apply_dge(&mut dw, &w, d_out, d_in);
        for &f in &dw {
            assert!(f > 0.0 && f <= DGE_CAP, "factor {f} out of range");
        }
        let mean = dw.iter().map(|&v| v as f64).sum::<f64>() / dw.len() as f64;
        assert!((mean - 1.0).abs() < 0.35, "DGE mean factor drifted: {mean}");
        // the group max itself gets the shallow end of the surrogate
        let amax_idx = (0..d_in)
            .max_by(|&a, &b| w[a].abs().partial_cmp(&w[b].abs()).unwrap())
            .unwrap();
        assert!(dw[amax_idx] <= 1.0);
    }

    #[test]
    fn abs_quantile_picks_the_tail() {
        let x: Vec<f32> = (1..=100).map(|v| v as f32).collect();
        let tau = abs_quantile(&x, 0.99);
        assert!(tau >= 99.0 && tau <= 100.0, "tau {tau}");
        assert_eq!(abs_quantile(&[0.0; 8], 0.99), 0.0);
    }

    #[test]
    fn quartet_backward_carries_trust_mask() {
        // QuEST forward must hand its trust mask to the backward, and the
        // masked gradient path must stay finite under extreme inputs.
        let be = ScalarBackend;
        let mut rng = Rng::new(5);
        let (rows, d_in, d_out) = (1, 32, 32);
        let layer = QuantLinear::init(d_out, d_in, &mut rng);
        let mut x = rng.gaussian_vec(rows * d_in, 1.0);
        x[3] = 1000.0;
        let (y, cache) = layer.forward(&x, rows, TrainMethod::Quartet, &be, &mut Rng::new(6));
        assert!(cache.mask_x.is_some(), "quest forward must carry a mask");
        let dy: Vec<f32> = y.iter().map(|_| 1.0).collect();
        let (dx, _) = layer.backward(&dy, &cache, rows, TrainMethod::Quartet, &be, &mut Rng::new(7));
        assert!(dx.iter().all(|v| v.is_finite()));
    }
}
