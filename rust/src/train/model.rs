//! The native MLP language model: order-2 next-token prediction over the
//! Zipf–Markov corpus.
//!
//! Architecture: `concat(emb[t-1], emb[t])` → QuantLinear stack (ReLU
//! between layers) → vocab logits → softmax cross-entropy. Embeddings
//! stay f32 (the paper quantizes only the linear layers); every linear
//! runs under the model's [`TrainMethod`].
//!
//! Checkpoints are single JSON files (`kind: "native-mlp-lm"`) holding
//! the config and raw f32 weights — `serve::CpuPrefillEngine` loads them
//! and re-quantizes the weights once into deployed MXFP4 form.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::kernels::Backend;
use crate::train::layer::{LinearCache, QuantLinear};
use crate::train::{ModelConfig, TrainMethod};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-tensor gradients of one loss evaluation, same layout as the params.
pub struct Grads {
    pub tok_emb: Vec<f32>,
    pub layers: Vec<Vec<f32>>,
}

/// The model: f32 token embedding + quantized linear stack.
#[derive(Debug, Clone)]
pub struct MlpLm {
    pub cfg: ModelConfig,
    /// `[vocab, d_emb]` row-major
    pub tok_emb: Vec<f32>,
    pub layers: Vec<QuantLinear>,
}

impl MlpLm {
    pub fn init(cfg: ModelConfig, seed: u64) -> Result<MlpLm> {
        cfg.validate()?;
        let mut rng = Rng::new(seed);
        let tok_emb = rng.gaussian_vec(cfg.vocab * cfg.d_emb, 1.0);
        let layers = cfg
            .layer_dims()
            .into_iter()
            .map(|(o, i)| QuantLinear::init(o, i, &mut rng))
            .collect();
        Ok(MlpLm { cfg, tok_emb, layers })
    }

    /// Gather `[B, 2·d_emb]` features for a batch of (t-1, t) contexts.
    pub fn features(&self, ctx: &[(u32, u32)]) -> Vec<f32> {
        let d = self.cfg.d_emb;
        let mut x = vec![0.0f32; ctx.len() * 2 * d];
        for (s, &(a, b)) in ctx.iter().enumerate() {
            write_pair_features(
                &self.tok_emb,
                d,
                self.cfg.vocab,
                a as usize,
                b as usize,
                &mut x[s * 2 * d..(s + 1) * 2 * d],
            );
        }
        x
    }

    /// Inference logits `[B, vocab]` (no caches; forward precision only —
    /// every method's forward is deterministic, so this is eval-stable).
    pub fn logits(&self, ctx: &[(u32, u32)], be: &dyn Backend) -> Vec<f32> {
        let b = ctx.len();
        let mut rng = Rng::new(0);
        let mut x = self.features(ctx);
        let last = self.layers.len() - 1;
        for (li, layer) in self.layers.iter().enumerate() {
            let (mut y, _) = layer.forward(&x, b, self.cfg.method, be, &mut rng);
            if li < last {
                relu(&mut y);
            }
            x = y;
        }
        x
    }

    /// Mean cross-entropy of a batch under the forward precision.
    pub fn eval_loss(&self, ctx: &[(u32, u32)], targets: &[u32], be: &dyn Backend) -> f64 {
        let logits = self.logits(ctx, be);
        let (loss, _) = softmax_xent(&logits, targets, self.cfg.vocab, false);
        loss
    }

    /// One full forward/backward: returns the mean training loss and the
    /// gradients of every parameter tensor.
    pub fn loss_and_grads(
        &self,
        ctx: &[(u32, u32)],
        targets: &[u32],
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (f64, Grads) {
        let b = ctx.len();
        assert_eq!(b, targets.len());
        let last = self.layers.len() - 1;

        let mut x = self.features(ctx);
        let mut caches: Vec<LinearCache> = Vec::with_capacity(self.layers.len());
        for (li, layer) in self.layers.iter().enumerate() {
            let (mut y, cache) = layer.forward(&x, b, self.cfg.method, be, rng);
            caches.push(cache);
            if li < last {
                relu(&mut y);
            }
            x = y;
        }
        let (loss, dlogits) = softmax_xent(&x, targets, self.cfg.vocab, true);
        let mut dcur = dlogits.expect("grad requested");

        let mut grads = Grads {
            tok_emb: vec![0.0f32; self.tok_emb.len()],
            layers: vec![Vec::new(); self.layers.len()],
        };
        for li in (0..self.layers.len()).rev() {
            let (dx, dw) =
                self.layers[li].backward(&dcur, &caches[li], b, self.cfg.method, be, rng);
            grads.layers[li] = dw;
            if li > 0 {
                // the input to layer li was ReLU(previous output): gate
                let gate = &caches[li].x;
                dcur = dx
                    .iter()
                    .zip(gate)
                    .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                    .collect();
            } else {
                // scatter the feature gradient into the two embedding rows
                let d = self.cfg.d_emb;
                let v = self.cfg.vocab;
                for (s, &(a, p)) in ctx.iter().enumerate() {
                    let row = &dx[s * 2 * d..(s + 1) * 2 * d];
                    let ea = (a as usize % v) * d;
                    let ep = (p as usize % v) * d;
                    for i in 0..d {
                        grads.tok_emb[ea + i] += row[i];
                        grads.tok_emb[ep + i] += row[d + i];
                    }
                }
            }
        }
        (loss, grads)
    }

    // ---- checkpointing ----------------------------------------------------

    /// Write the checkpoint JSON (compact form; weight arrays dominate).
    pub fn save(&self, path: &Path) -> Result<()> {
        let c = &self.cfg;
        let j = Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            ("kind", Json::str("native-mlp-lm")),
            ("method", Json::str(c.method.name())),
            ("vocab", Json::num(c.vocab as f64)),
            ("d_emb", Json::num(c.d_emb as f64)),
            ("d_hidden", Json::num(c.d_hidden as f64)),
            ("n_hidden", Json::num(c.n_hidden as f64)),
            ("tok_emb", Json::f32s(&self.tok_emb)),
            (
                "layers",
                Json::array(self.layers.iter().map(|l| Json::f32s(&l.w))),
            ),
        ]);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, j.to_string())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Load and shape-check a checkpoint written by [`MlpLm::save`].
    pub fn load(path: &Path) -> Result<MlpLm> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("loading {}", path.display()))
    }

    /// Build from already-parsed checkpoint JSON (weight dumps are large;
    /// `NativeModel::load` parses once and dispatches here by `kind`).
    pub fn from_json(j: &Json) -> Result<MlpLm> {
        let kind = j.req("kind")?.as_str().unwrap_or("");
        if kind != "native-mlp-lm" {
            bail!("not a native MLP checkpoint (kind {kind:?})");
        }
        let cfg = ModelConfig {
            vocab: j.req("vocab")?.as_usize().unwrap_or(0),
            d_emb: j.req("d_emb")?.as_usize().unwrap_or(0),
            d_hidden: j.req("d_hidden")?.as_usize().unwrap_or(0),
            n_hidden: j.req("n_hidden")?.as_usize().unwrap_or(0),
            method: TrainMethod::parse(j.req("method")?.as_str().unwrap_or(""))?,
        };
        cfg.validate()?;
        let f32s = |v: &Json, what: &str| -> Result<Vec<f32>> {
            v.as_arr()
                .ok_or_else(|| anyhow!("{what} not an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow!("{what}: non-numeric entry"))
                })
                .collect()
        };
        let tok_emb = f32s(j.req("tok_emb")?, "tok_emb")?;
        let want_emb = cfg.vocab.checked_mul(cfg.d_emb).ok_or_else(|| {
            anyhow!(
                "embedding shape {}x{} overflows usize — corrupt or hostile dims",
                cfg.vocab,
                cfg.d_emb
            )
        })?;
        if tok_emb.len() != want_emb {
            bail!("tok_emb has {} values, config wants {}", tok_emb.len(), want_emb);
        }
        let raw = j.req("layers")?.as_arr().ok_or_else(|| anyhow!("layers not an array"))?;
        let dims = cfg.layer_dims();
        if raw.len() != dims.len() {
            bail!("checkpoint has {} layers, config wants {}", raw.len(), dims.len());
        }
        let mut layers = Vec::with_capacity(dims.len());
        for (li, ((o, i), v)) in dims.into_iter().zip(raw).enumerate() {
            let w = f32s(v, "layer weight")?;
            let want = o.checked_mul(i).ok_or_else(|| {
                anyhow!("layer {li} shape {o}x{i} overflows usize — corrupt or hostile dims")
            })?;
            if w.len() != want {
                bail!("layer {li} has {} values, wants {}x{}", w.len(), o, i);
            }
            layers.push(QuantLinear::from_weights(o, i, w));
        }
        Ok(MlpLm { cfg, tok_emb, layers })
    }
}

/// The model's per-position input layout, shared with the serving engine
/// so training and inference can never drift apart: one feature row is
/// `concat(emb[prev2], emb[prev])`.
pub(crate) fn write_pair_features(
    tok_emb: &[f32],
    d_emb: usize,
    vocab: usize,
    prev2: usize,
    prev: usize,
    dst: &mut [f32],
) {
    let a = (prev2 % vocab) * d_emb;
    let b = (prev % vocab) * d_emb;
    dst[..d_emb].copy_from_slice(&tok_emb[a..a + d_emb]);
    dst[d_emb..2 * d_emb].copy_from_slice(&tok_emb[b..b + d_emb]);
}

#[inline]
pub(crate) fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Mean softmax cross-entropy over a `[B, vocab]` logit batch; when
/// `want_grad`, also dL/dlogits (already divided by B).
pub fn softmax_xent(
    logits: &[f32],
    targets: &[u32],
    vocab: usize,
    want_grad: bool,
) -> (f64, Option<Vec<f32>>) {
    let b = targets.len();
    assert_eq!(logits.len(), b * vocab);
    let mut grad = if want_grad { Some(vec![0.0f32; b * vocab]) } else { None };
    let mut loss = 0.0f64;
    for s in 0..b {
        let row = &logits[s * vocab..(s + 1) * vocab];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f64;
        for &l in row {
            z += ((l - max) as f64).exp();
        }
        let t = targets[s] as usize % vocab;
        loss += z.ln() - (row[t] - max) as f64;
        if let Some(g) = grad.as_mut() {
            let grow = &mut g[s * vocab..(s + 1) * vocab];
            for (j, &l) in row.iter().enumerate() {
                let p = (((l - max) as f64).exp() / z) as f32;
                grow[j] = (p - if j == t { 1.0 } else { 0.0 }) / b as f32;
            }
        }
    }
    (loss / b as f64, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    fn cfg(method: TrainMethod) -> ModelConfig {
        ModelConfig { vocab: 32, d_emb: 16, d_hidden: 64, n_hidden: 1, method }
    }

    fn batch(n: usize, vocab: u32, seed: u64) -> (Vec<(u32, u32)>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let ctx = (0..n)
            .map(|_| (rng.below(vocab as usize) as u32, rng.below(vocab as usize) as u32))
            .collect();
        let tgt = (0..n).map(|_| rng.below(vocab as usize) as u32).collect();
        (ctx, tgt)
    }

    #[test]
    fn init_loss_near_log_vocab() {
        for method in TrainMethod::ALL {
            let m = MlpLm::init(cfg(method), 1).unwrap();
            let (ctx, tgt) = batch(64, 32, 2);
            let loss = m.eval_loss(&ctx, &tgt, &ScalarBackend);
            let expect = (32f64).ln();
            assert!(
                (loss - expect).abs() < 1.2,
                "{}: init loss {loss} vs ln(V) {expect}",
                method.name()
            );
        }
    }

    #[test]
    fn softmax_grad_sums_to_zero_rowwise() {
        let logits = vec![0.1f32, 2.0, -1.0, 0.5, 1.0, 0.0, 0.0, -2.0];
        let (_, g) = softmax_xent(&logits, &[1, 3], 4, true);
        let g = g.unwrap();
        for s in 0..2 {
            let sum: f32 = g[s * 4..(s + 1) * 4].iter().sum();
            assert!(sum.abs() < 1e-6, "row {s} grad sum {sum}");
        }
        // target coordinate is negative (pulls probability up)
        assert!(g[1] < 0.0 && g[4 + 3] < 0.0);
    }

    #[test]
    fn grads_have_param_shapes() {
        let m = MlpLm::init(cfg(TrainMethod::Quartet), 3).unwrap();
        let (ctx, tgt) = batch(16, 32, 4);
        let (loss, grads) =
            m.loss_and_grads(&ctx, &tgt, &ScalarBackend, &mut Rng::new(5));
        assert!(loss.is_finite());
        assert_eq!(grads.tok_emb.len(), m.tok_emb.len());
        assert_eq!(grads.layers.len(), m.layers.len());
        for (g, l) in grads.layers.iter().zip(&m.layers) {
            assert_eq!(g.len(), l.w.len());
        }
        // the embedding rows of unseen tokens got no gradient
        let seen: std::collections::BTreeSet<usize> = ctx
            .iter()
            .flat_map(|&(a, b)| [a as usize, b as usize])
            .collect();
        let d = m.cfg.d_emb;
        for t in 0..m.cfg.vocab {
            let row_norm: f32 = grads.tok_emb[t * d..(t + 1) * d]
                .iter()
                .map(|v| v.abs())
                .sum();
            if !seen.contains(&t) {
                assert_eq!(row_norm, 0.0, "unseen token {t} has gradient");
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_bit_exact() {
        let m = MlpLm::init(cfg(TrainMethod::Quartet), 7).unwrap();
        let path = std::env::temp_dir()
            .join(format!("native_ckpt_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let back = MlpLm::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(back.cfg.vocab, m.cfg.vocab);
        assert_eq!(back.cfg.method, m.cfg.method);
        assert_eq!(back.tok_emb, m.tok_emb);
        for (a, b) in back.layers.iter().zip(&m.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!((a.d_out, a.d_in), (b.d_out, b.d_in));
        }
    }

    #[test]
    fn load_rejects_shape_mismatch() {
        let m = MlpLm::init(cfg(TrainMethod::F32), 9).unwrap();
        let path = std::env::temp_dir()
            .join(format!("native_ckpt_bad_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // corrupt the declared hidden width (keep it MX-aligned so the
        // failure is the shape check, not validate())
        let bad = text.replace("\"d_hidden\":64", "\"d_hidden\":128");
        std::fs::write(&path, bad).unwrap();
        assert!(MlpLm::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn from_json_rejects_overflowing_dims() {
        // vocab * d_emb == 2^64: a hostile header must die in checked_mul
        // with a descriptive error, never wrap and "pass" the shape check
        let j = Json::from_pairs(vec![
            ("kind", Json::str("native-mlp-lm")),
            ("method", Json::str("quartet")),
            ("vocab", Json::num((1u64 << 59) as f64)),
            ("d_emb", Json::num(32.0)),
            ("d_hidden", Json::num(64.0)),
            ("n_hidden", Json::num(1.0)),
            ("tok_emb", Json::f32s(&[0.0; 4])),
            ("layers", Json::array(std::iter::empty())),
        ]);
        let err = MlpLm::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("overflows"), "got: {err}");
    }
}
