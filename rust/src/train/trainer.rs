//! The native training loop: pure-Rust Quartet pre-training on the
//! synthetic corpus, emitting the same [`RunRecord`]s the PJRT sweeps
//! write so `scaling::fit` (and the fig1 benches) consume native runs
//! without knowing which trainer produced them.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::runrecord::RunRecord;
use crate::data::corpus::{Corpus, CorpusConfig, CorpusStream, Split};
use crate::kernels::Backend;
use crate::train::dist::{
    dist_loss_and_grads_mlp, dist_loss_and_grads_transformer, ring_allreduce_bytes, CommsBytes,
    DistOptions, Topology,
};
use crate::train::model::MlpLm;
use crate::train::topo::{
    dist_loss_and_grads_topo_mlp, dist_loss_and_grads_topo_transformer, validate_topo_mlp,
    validate_topo_transformer,
};
use crate::train::optim::Adam;
use crate::train::transformer::{TransformerConfig, TransformerLm};
use crate::train::ModelConfig;
use crate::util::rng::Rng;

/// Run-level knobs of a native training run.
#[derive(Debug, Clone)]
pub struct NativeTrainOptions {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    /// validate every N steps (0 = only at the start and end)
    pub eval_every: usize,
    /// batches per validation pass
    pub eval_batches: usize,
    pub log_every: usize,
    pub verbose: bool,
    /// corpus knobs; `vocab` is overridden by the model config
    pub corpus: CorpusConfig,
    /// data-parallel axis: `None` keeps the single-worker path
    /// bit-identical to its historical behaviour; `Some` shards every
    /// global batch into [`DistOptions::shards`] logical shards computed
    /// by [`DistOptions::workers`] threads and all-reduced per
    /// [`DistOptions::reduce`] (see [`crate::train::dist`]).
    pub dist: Option<DistOptions>,
    /// tensor/pipeline axes: `None` keeps the plain (data-parallel or
    /// single-worker) step; `Some` routes every step through
    /// [`crate::train::topo`] — `ts`-way tensor-sharded matmuls on `tp`
    /// ranks, `pp` 1F1B pipeline stages, activations crossing block
    /// boundaries and TP collectives in [`Topology::wire`] precision.
    /// Combines with `dist` (which keeps its DP meaning); without an
    /// explicit `dist` the topology runs over [`DistOptions::default`]
    /// shards.
    pub topo: Option<Topology>,
}

impl Default for NativeTrainOptions {
    fn default() -> Self {
        NativeTrainOptions {
            steps: 400,
            batch: 32,
            lr: 8e-3,
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 50,
            verbose: false,
            corpus: CorpusConfig::default(),
            dist: None,
            topo: None,
        }
    }
}

/// The DistOptions a topology-aware run actually uses: the explicit DP
/// axis if one was given, else the default shard structure.
fn topo_dist(opts: &NativeTrainOptions) -> DistOptions {
    opts.dist.clone().unwrap_or_default()
}

/// Distilled record metadata of the distribution axes:
/// `(workers, grad_shards, reduce name, tp, pp, wire name)`.
fn dist_record_fields(
    dist: &Option<DistOptions>,
    topo: &Option<Topology>,
) -> (usize, usize, String, usize, usize, String) {
    let (workers, shards, reduce) = match (dist, topo) {
        (None, None) => (1, 1, "none".to_string()),
        (None, Some(_)) => {
            let d = DistOptions::default();
            (d.effective_workers(), d.shards, d.reduce.name().to_string())
        }
        (Some(d), _) => (d.effective_workers(), d.shards, d.reduce.name().to_string()),
    };
    let (tp, pp, wire) = match topo {
        None => (1, 1, "none".to_string()),
        Some(t) => (t.effective_tp(), t.pp.max(1), t.wire.name().to_string()),
    };
    (workers, shards, reduce, tp, pp, wire)
}

/// Fold the per-step comms accounting of whichever distribution path ran:
/// the topology path reports per-collective volumes directly; the plain
/// DP path only rings the gradient payload.
fn step_comms(
    dist: &Option<DistOptions>,
    topo: &Option<Topology>,
    topo_comms: CommsBytes,
    dp_payload: f64,
) -> CommsBytes {
    match (dist, topo) {
        (_, Some(_)) => topo_comms,
        (Some(d), None) => CommsBytes {
            allreduce: ring_allreduce_bytes(d.effective_workers(), dp_payload),
            ..CommsBytes::default()
        },
        (None, None) => CommsBytes::default(),
    }
}

/// Streaming (t-1, t) → t+1 sample source over a corpus split — the
/// native model's batcher (each predicted token is one training token in
/// the scaling-law D accounting).
pub struct Triples<'a> {
    stream: CorpusStream<'a>,
    prev2: u32,
    prev: u32,
}

impl<'a> Triples<'a> {
    pub fn new(corpus: &'a Corpus, split: Split) -> Triples<'a> {
        let mut stream = corpus.stream(split, 0);
        let prev2 = stream.next_token();
        let prev = stream.next_token();
        Triples { stream, prev2, prev }
    }

    /// Next `n` overlapping samples: contexts and their target tokens.
    pub fn next_batch(&mut self, n: usize) -> (Vec<(u32, u32)>, Vec<u32>) {
        let mut ctx = Vec::with_capacity(n);
        let mut tgt = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.stream.next_token();
            ctx.push((self.prev2, self.prev));
            tgt.push(t);
            self.prev2 = self.prev;
            self.prev = t;
        }
        (ctx, tgt)
    }
}

/// Mean validation loss over a fresh val-split sample (deterministic:
/// every forward precision on the method axis is noise-free at eval).
/// All `batches·batch` samples run as one forward so the frozen weights
/// are Hadamard-transformed and quantized exactly once per eval pass.
pub fn eval_val_loss(
    model: &MlpLm,
    corpus: &Corpus,
    be: &dyn Backend,
    batches: usize,
    batch: usize,
) -> f64 {
    let mut triples = Triples::new(corpus, Split::Val);
    let (ctx, tgt) = triples.next_batch(batches.max(1) * batch.max(1));
    model.eval_loss(&ctx, &tgt, be)
}

/// Train a native model from scratch; returns the run record (val_curve
/// starts with the step-0 loss, so convergence is checkable from the
/// record alone) and the trained model for checkpointing/serving.
pub fn train_native(
    cfg: &ModelConfig,
    opts: &NativeTrainOptions,
    be: &dyn Backend,
) -> Result<(RunRecord, MlpLm)> {
    cfg.validate_for_training()?;
    if let Some(t) = &opts.topo {
        validate_topo_mlp(cfg, t)?;
        topo_dist(opts).validate(opts.batch)?;
    } else if let Some(d) = &opts.dist {
        d.validate(opts.batch)?;
    }
    let corpus = Corpus::new(CorpusConfig { vocab: cfg.vocab, ..opts.corpus.clone() });
    let mut model = MlpLm::init(cfg.clone(), opts.seed)?;
    let mut sizes = vec![model.tok_emb.len()];
    sizes.extend(model.layers.iter().map(|l| l.w.len()));
    let mut adam = Adam::new(&sizes, opts.lr);
    let mut rng = Rng::new(opts.seed ^ 0xD1CE_5EED);
    let mut triples = Triples::new(&corpus, Split::Train);

    let name = match (&opts.dist, &opts.topo) {
        (None, None) => format!("native-h{}-{}", cfg.d_hidden, cfg.method.name()),
        (Some(d), None) => format!(
            "native-h{}-{}-w{}-{}",
            cfg.d_hidden,
            cfg.method.name(),
            d.effective_workers(),
            d.reduce.name()
        ),
        (_, Some(t)) => format!(
            "native-h{}-{}-w{}-tp{}-pp{}-{}",
            cfg.d_hidden,
            cfg.method.name(),
            topo_dist(opts).effective_workers(),
            t.effective_tp(),
            t.pp.max(1),
            t.wire.name()
        ),
    };
    let mut train_curve = Vec::new();
    let mut val_curve = Vec::new();
    let init_val = eval_val_loss(&model, &corpus, be, opts.eval_batches, opts.batch);
    val_curve.push((0, init_val));
    if opts.verbose {
        eprintln!("[{name}] step 0/{} val loss {init_val:.4}", opts.steps);
    }

    let t0 = Instant::now();
    // wall/throughput accounting covers *training* work only: periodic
    // eval time is subtracted and the final eval happens after the clock
    // is read, so tok/s comparisons between backends stay honest
    let mut eval_secs = 0.0f64;
    let mut diverged = false;
    let mut steps_done = 0usize;
    let mut comms_payload = 0.0f64;
    let mut topo_comms = CommsBytes::default();
    let topo_d = opts.topo.as_ref().map(|_| topo_dist(opts));
    for step in 1..=opts.steps {
        let (ctx, tgt) = triples.next_batch(opts.batch);
        let (loss, grads) = if let Some(t) = &opts.topo {
            let (l, g, c) = dist_loss_and_grads_topo_mlp(
                &model,
                &ctx,
                &tgt,
                topo_d.as_ref().unwrap(),
                t,
                be,
                opts.seed,
                step,
            );
            topo_comms = c;
            (l, g)
        } else {
            match &opts.dist {
                None => model.loss_and_grads(&ctx, &tgt, be, &mut rng),
                Some(d) => {
                    let (l, g, payload) =
                        dist_loss_and_grads_mlp(&model, &ctx, &tgt, d, be, opts.seed, step);
                    comms_payload = payload;
                    (l, g)
                }
            }
        };
        // the diverged step still consumed its batch: count it, so the
        // record's steps/tokens agree with the curves
        steps_done = step;
        if !loss.is_finite() || loss > 20.0 {
            diverged = true;
            train_curve.push((step, loss));
            break;
        }
        // cosine decay to ~0: late-run SR noise averages out, so the
        // unbiased methods converge to the full-precision fixed point
        // while RTN's bias floor stays — the separation Table 3 measures
        let progress = (step - 1) as f32 / opts.steps as f32;
        adam.lr = opts.lr * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        adam.begin_step();
        adam.update(0, &mut model.tok_emb, &grads.tok_emb);
        for (i, layer) in model.layers.iter_mut().enumerate() {
            adam.update(i + 1, &mut layer.w, &grads.layers[i]);
        }

        if step % opts.log_every.max(1) == 0 || step == opts.steps {
            train_curve.push((step, loss));
            if opts.verbose {
                eprintln!("[{name}] step {step}/{} train loss {loss:.4}", opts.steps);
            }
        }
        if opts.eval_every > 0 && step % opts.eval_every == 0 && step < opts.steps {
            let e0 = Instant::now();
            let vl = eval_val_loss(&model, &corpus, be, opts.eval_batches, opts.batch);
            eval_secs += e0.elapsed().as_secs_f64();
            val_curve.push((step, vl));
            if opts.verbose {
                eprintln!("[{name}] step {step}/{} val loss {vl:.4}", opts.steps);
            }
        }
    }
    let wall = (t0.elapsed().as_secs_f64() - eval_secs).max(0.0);

    let final_val = if diverged {
        f64::NAN
    } else {
        eval_val_loss(&model, &corpus, be, opts.eval_batches, opts.batch)
    };
    val_curve.push((steps_done, final_val));
    let tokens = steps_done * opts.batch;
    let params = cfg.non_embedding_params();
    let (workers, grad_shards, reduce, tp, pp, wire) =
        dist_record_fields(&opts.dist, &opts.topo);
    let comms = step_comms(&opts.dist, &opts.topo, topo_comms, comms_payload);

    let rec = RunRecord {
        artifact: name,
        size: format!("h{}", cfg.d_hidden),
        method: cfg.method.name().to_string(),
        non_embedding_params: params,
        tokens,
        steps: steps_done,
        ratio: tokens as f64 / params.max(1) as f64,
        seed: opts.seed,
        train_curve,
        val_curve,
        final_val_loss: final_val,
        wall_secs: wall,
        tokens_per_sec: tokens as f64 / wall.max(1e-9),
        diverged,
        workers,
        grad_shards,
        reduce,
        tp,
        pp,
        wire,
        comms_bytes_per_step: comms.total(),
        comms_allreduce_bytes_per_step: comms.allreduce,
        comms_reduce_scatter_bytes_per_step: comms.reduce_scatter,
        comms_all_gather_bytes_per_step: comms.all_gather,
        comms_p2p_bytes_per_step: comms.p2p,
    };
    Ok((rec, model))
}

/// Streaming `[b, s+1]` window source over a corpus split — the
/// transformer's batcher. Windows are consecutive and non-overlapping, so
/// every predicted position is one fresh training token in the
/// scaling-law D accounting.
pub struct SeqWindows<'a> {
    stream: CorpusStream<'a>,
}

impl<'a> SeqWindows<'a> {
    pub fn new(corpus: &'a Corpus, split: Split) -> SeqWindows<'a> {
        SeqWindows { stream: corpus.stream(split, 0) }
    }

    /// Next `b` windows of `s + 1` tokens each, row-major `[b, s+1]`.
    pub fn next_batch(&mut self, b: usize, s: usize) -> Vec<u32> {
        (0..b * (s + 1)).map(|_| self.stream.next_token()).collect()
    }
}

/// Mean validation loss of a transformer over fresh val-split windows
/// (deterministic: every forward precision is noise-free at eval).
pub fn eval_val_loss_transformer(
    model: &TransformerLm,
    corpus: &Corpus,
    be: &dyn Backend,
    batches: usize,
    batch: usize,
) -> f64 {
    let b = batches.max(1) * batch.max(1);
    let mut windows = SeqWindows::new(corpus, Split::Val);
    let toks = windows.next_batch(b, model.cfg.seq);
    model.eval_loss(&toks, b, be)
}

/// Train a native Llama-style transformer from scratch; returns the run
/// record (val_curve starts with the step-0 loss) and the trained model
/// for checkpointing/serving. The loop mirrors [`train_native`] — Adam,
/// cosine lr decay, divergence detection, eval wall-time subtraction — so
/// records from both architectures feed `scaling::fit` identically.
pub fn train_native_transformer(
    cfg: &TransformerConfig,
    opts: &NativeTrainOptions,
    be: &dyn Backend,
) -> Result<(RunRecord, TransformerLm)> {
    cfg.validate_for_training()?;
    if let Some(t) = &opts.topo {
        validate_topo_transformer(cfg, t)?;
        topo_dist(opts).validate(opts.batch)?;
    } else if let Some(d) = &opts.dist {
        d.validate(opts.batch)?;
    }
    let corpus = Corpus::new(CorpusConfig { vocab: cfg.vocab, ..opts.corpus.clone() });
    let mut model = TransformerLm::init(cfg.clone(), opts.seed)?;
    let sizes = model.param_sizes();
    let mut adam = Adam::new(&sizes, opts.lr);
    let mut rng = Rng::new(opts.seed ^ 0xD1CE_5EED);
    let mut windows = SeqWindows::new(&corpus, Split::Train);

    let name = match (&opts.dist, &opts.topo) {
        (None, None) => {
            format!("native-tf-d{}L{}-{}", cfg.d_model, cfg.n_layers, cfg.method.name())
        }
        (Some(d), None) => format!(
            "native-tf-d{}L{}-{}-w{}-{}",
            cfg.d_model,
            cfg.n_layers,
            cfg.method.name(),
            d.effective_workers(),
            d.reduce.name()
        ),
        (_, Some(t)) => format!(
            "native-tf-d{}L{}-{}-w{}-tp{}-pp{}-{}",
            cfg.d_model,
            cfg.n_layers,
            cfg.method.name(),
            topo_dist(opts).effective_workers(),
            t.effective_tp(),
            t.pp.max(1),
            t.wire.name()
        ),
    };
    let mut train_curve = Vec::new();
    let mut val_curve = Vec::new();
    let init_val = eval_val_loss_transformer(&model, &corpus, be, opts.eval_batches, opts.batch);
    val_curve.push((0, init_val));
    if opts.verbose {
        eprintln!("[{name}] step 0/{} val loss {init_val:.4}", opts.steps);
    }

    let t0 = Instant::now();
    let mut eval_secs = 0.0f64;
    let mut diverged = false;
    let mut steps_done = 0usize;
    let mut comms_payload = 0.0f64;
    let mut topo_comms = CommsBytes::default();
    let topo_d = opts.topo.as_ref().map(|_| topo_dist(opts));
    for step in 1..=opts.steps {
        let toks = windows.next_batch(opts.batch, cfg.seq);
        let (loss, grads) = if let Some(t) = &opts.topo {
            let (l, g, c) = dist_loss_and_grads_topo_transformer(
                &model,
                &toks,
                opts.batch,
                topo_d.as_ref().unwrap(),
                t,
                be,
                opts.seed,
                step,
            );
            topo_comms = c;
            (l, g)
        } else {
            match &opts.dist {
                None => model.loss_and_grads(&toks, opts.batch, be, &mut rng),
                Some(d) => {
                    let (l, g, payload) = dist_loss_and_grads_transformer(
                        &model, &toks, opts.batch, d, be, opts.seed, step,
                    );
                    comms_payload = payload;
                    (l, g)
                }
            }
        };
        steps_done = step;
        if !loss.is_finite() || loss > 20.0 {
            diverged = true;
            train_curve.push((step, loss));
            break;
        }
        let progress = (step - 1) as f32 / opts.steps as f32;
        adam.lr = opts.lr * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        adam.begin_step();
        // slot order is the TransformerLm::param_sizes contract
        let mut slot = 0usize;
        adam.update(slot, &mut model.tok_emb, &grads.tok_emb);
        slot += 1;
        for (bi, block) in model.blocks.iter_mut().enumerate() {
            let g = &grads.blocks[bi];
            adam.update(slot, &mut block.attn_norm, &g.attn_norm);
            adam.update(slot + 1, &mut block.wq.w, &g.wq);
            adam.update(slot + 2, &mut block.wk.w, &g.wk);
            adam.update(slot + 3, &mut block.wv.w, &g.wv);
            adam.update(slot + 4, &mut block.wo.w, &g.wo);
            adam.update(slot + 5, &mut block.mlp_norm, &g.mlp_norm);
            adam.update(slot + 6, &mut block.w_gate.w, &g.w_gate);
            adam.update(slot + 7, &mut block.w_up.w, &g.w_up);
            adam.update(slot + 8, &mut block.w_down.w, &g.w_down);
            slot += 9;
        }
        adam.update(slot, &mut model.final_norm, &grads.final_norm);

        if step % opts.log_every.max(1) == 0 || step == opts.steps {
            train_curve.push((step, loss));
            if opts.verbose {
                eprintln!("[{name}] step {step}/{} train loss {loss:.4}", opts.steps);
            }
        }
        if opts.eval_every > 0 && step % opts.eval_every == 0 && step < opts.steps {
            let e0 = Instant::now();
            let vl =
                eval_val_loss_transformer(&model, &corpus, be, opts.eval_batches, opts.batch);
            eval_secs += e0.elapsed().as_secs_f64();
            val_curve.push((step, vl));
            if opts.verbose {
                eprintln!("[{name}] step {step}/{} val loss {vl:.4}", opts.steps);
            }
        }
    }
    let wall = (t0.elapsed().as_secs_f64() - eval_secs).max(0.0);

    let final_val = if diverged {
        f64::NAN
    } else {
        eval_val_loss_transformer(&model, &corpus, be, opts.eval_batches, opts.batch)
    };
    val_curve.push((steps_done, final_val));
    // each window predicts seq tokens
    let tokens = steps_done * opts.batch * cfg.seq;
    let params = cfg.non_embedding_params();
    let (workers, grad_shards, reduce, tp, pp, wire) =
        dist_record_fields(&opts.dist, &opts.topo);
    let comms = step_comms(&opts.dist, &opts.topo, topo_comms, comms_payload);

    let rec = RunRecord {
        artifact: name,
        size: format!("d{}L{}", cfg.d_model, cfg.n_layers),
        method: cfg.method.name().to_string(),
        non_embedding_params: params,
        tokens,
        steps: steps_done,
        ratio: tokens as f64 / params.max(1) as f64,
        seed: opts.seed,
        train_curve,
        val_curve,
        final_val_loss: final_val,
        wall_secs: wall,
        tokens_per_sec: tokens as f64 / wall.max(1e-9),
        diverged,
        workers,
        grad_shards,
        reduce,
        tp,
        pp,
        wire,
        comms_bytes_per_step: comms.total(),
        comms_allreduce_bytes_per_step: comms.allreduce,
        comms_reduce_scatter_bytes_per_step: comms.reduce_scatter,
        comms_all_gather_bytes_per_step: comms.all_gather,
        comms_p2p_bytes_per_step: comms.p2p,
    };
    Ok((rec, model))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;
    use crate::train::TrainMethod;

    fn small_cfg(method: TrainMethod) -> ModelConfig {
        ModelConfig { vocab: 32, d_emb: 16, d_hidden: 64, n_hidden: 0, method }
    }

    fn small_opts() -> NativeTrainOptions {
        NativeTrainOptions {
            steps: 60,
            batch: 16,
            lr: 1e-2,
            seed: 3,
            eval_every: 30,
            eval_batches: 4,
            log_every: 20,
            ..NativeTrainOptions::default()
        }
    }

    #[test]
    fn triples_are_consistent_windows() {
        let corpus = Corpus::new(CorpusConfig { vocab: 32, ..CorpusConfig::default() });
        let mut a = Triples::new(&corpus, Split::Train);
        let (ctx, tgt) = a.next_batch(32);
        // consecutive samples overlap: ctx[i+1] = (ctx[i].1, tgt[i])
        for i in 0..31 {
            assert_eq!(ctx[i + 1], (ctx[i].1, tgt[i]));
        }
        // deterministic
        let mut b = Triples::new(&corpus, Split::Train);
        assert_eq!(b.next_batch(32), (ctx, tgt));
    }

    #[test]
    fn f32_run_drops_loss_and_fills_record() {
        let (rec, model) =
            train_native(&small_cfg(TrainMethod::F32), &small_opts(), &ScalarBackend).unwrap();
        assert!(!rec.diverged);
        assert_eq!(rec.steps, 60);
        assert_eq!(rec.tokens, 60 * 16);
        assert_eq!(rec.method, "f32");
        assert!(rec.val_curve.len() >= 3, "init + periodic + final evals");
        let init = rec.val_curve[0].1;
        assert!(rec.final_val_loss < init, "no progress: {init} -> {}", rec.final_val_loss);
        assert_eq!(model.cfg.vocab, 32);
        // record is fit-consumable
        let run = rec.to_fit_run();
        assert!(run.n > 0.0 && run.d > 0.0 && run.loss.is_finite());
    }

    #[test]
    fn seeded_runs_are_reproducible() {
        let cfg = small_cfg(TrainMethod::Quartet);
        let opts = small_opts();
        let (a, _) = train_native(&cfg, &opts, &ScalarBackend).unwrap();
        let (b, _) = train_native(&cfg, &opts, &ScalarBackend).unwrap();
        assert_eq!(a.train_curve, b.train_curve, "stochastic rounding ignored the seed");
        assert_eq!(a.final_val_loss, b.final_val_loss);
    }

    #[test]
    fn seq_windows_are_deterministic_and_sized() {
        let corpus = Corpus::new(CorpusConfig { vocab: 32, ..CorpusConfig::default() });
        let mut a = SeqWindows::new(&corpus, Split::Train);
        let wa = a.next_batch(3, 8);
        assert_eq!(wa.len(), 3 * 9);
        let mut b = SeqWindows::new(&corpus, Split::Train);
        assert_eq!(b.next_batch(3, 8), wa);
        // consecutive batches continue the stream instead of repeating it
        assert_ne!(a.next_batch(3, 8), wa);
    }

    #[test]
    fn transformer_f32_run_drops_loss_and_fills_record() {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            seq: 8,
            method: TrainMethod::F32,
        };
        let opts = NativeTrainOptions {
            steps: 40,
            batch: 8,
            lr: 8e-3,
            seed: 3,
            eval_batches: 2,
            log_every: 20,
            ..NativeTrainOptions::default()
        };
        let (rec, model) = train_native_transformer(&cfg, &opts, &ScalarBackend).unwrap();
        assert!(!rec.diverged);
        assert_eq!(rec.steps, 40);
        assert_eq!(rec.tokens, 40 * 8 * 8);
        assert_eq!(rec.method, "f32");
        assert_eq!(rec.size, "d32L1");
        let init = rec.val_curve[0].1;
        assert!(
            rec.final_val_loss < init,
            "no progress: {init} -> {}",
            rec.final_val_loss
        );
        assert_eq!(model.cfg.vocab, 32);
        let run = rec.to_fit_run();
        assert!(run.n > 0.0 && run.d > 0.0 && run.loss.is_finite());
    }

    #[test]
    fn transformer_seeded_runs_are_reproducible() {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            seq: 8,
            method: TrainMethod::Quartet,
        };
        let opts = NativeTrainOptions {
            steps: 12,
            batch: 4,
            log_every: 4,
            ..NativeTrainOptions::default()
        };
        let (a, _) = train_native_transformer(&cfg, &opts, &ScalarBackend).unwrap();
        let (b, _) = train_native_transformer(&cfg, &opts, &ScalarBackend).unwrap();
        assert_eq!(a.train_curve, b.train_curve, "SR ignored the seed");
        assert_eq!(a.final_val_loss, b.final_val_loss);
    }
}
