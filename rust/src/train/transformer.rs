//! Llama-style FP4 decoder (`arch: transformer`): token embedding → N
//! blocks of {RMSNorm → causal multi-head attention with rotary position
//! embeddings → RMSNorm → SwiGLU MLP} → final RMSNorm → tied vocab head.
//!
//! The precision split follows the paper (and FP4-All-the-Way / NVFP4
//! pretraining): every matmul — Q/K/V/O, gate/up/down, and the tied
//! vocab head — runs on the [`TrainMethod`] axis through the QuEST
//! forward / SR-Hadamard backward of `train::layer`; norms, softmax,
//! rotary and the embedding *lookup* stay f32. "Tied" is weight sharing,
//! not precision: the head GEMM consumes a quantize-dequantized view of
//! the f32 embedding master each step (QAT-style), and its gradient —
//! the raw softmax logit gradient, the most heavy-tailed tensor in the
//! model — flows through the method's gradient quantizer. That last
//! point is where the naive `rtn` baseline collapses (its absmax RTN
//! rounds the bulk of the logit gradient to zero against the target
//! spike), reproducing Table 3's ordering; see
//! `tests/native_training.rs`. Serving only needs the forward, so the
//! vocab is unconstrained there; *training* quantizes the `[rows,
//! vocab]` logit gradient, so training requires `vocab % 32 == 0`
//! ([`TransformerConfig::validate_for_training`]).
//!
//! Attention itself runs through [`Backend::attention_causal`], whose
//! per-query-row determinism is what lets the serving engine decode
//! against a KV cache bit-identically to a full recompute.
//!
//! Checkpoints are single JSON files (`kind: "native-llama-lm"`) holding
//! the config and raw f32 weights; `serve::PackedWeightCache` re-quantizes
//! them once into deployed form.

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::kernels::scalar::dot_f32;
use crate::kernels::Backend;
use crate::quant::format::MXFP4;

/// MX-group alignment for the transformer's contraction axes (NVFP4's
/// 16-groups divide it, so one constraint covers the whole method axis).
const GROUP: usize = MXFP4.group;
use crate::train::layer::{backward_with, forward_with, LinearCache, QuantLinear};
use crate::train::model::softmax_xent;
use crate::train::TrainMethod;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// RMSNorm epsilon (added to the f64 mean square before the rsqrt).
pub const RMS_EPS: f64 = 1e-6;

/// Rotary base frequency (the Llama default).
pub const ROPE_THETA: f32 = 10_000.0;

/// Shape of the native transformer LM.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    /// SwiGLU hidden width (gate/up project d_model → d_ff)
    pub d_ff: usize,
    /// training sequence length (positions per sample)
    pub seq: usize,
    pub method: TrainMethod,
}

impl TransformerConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The quantized linears contract over `d_model` and `d_ff`, so both
    /// must be MX-group aligned; the vocab is free for the forward (the
    /// head contracts over `d_model`) — training adds its own constraint,
    /// see [`TransformerConfig::validate_for_training`].
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.d_model % GROUP == 0,
            "d_model must be a multiple of {GROUP} (got {})",
            self.d_model
        );
        ensure!(
            self.d_ff % GROUP == 0,
            "d_ff must be a multiple of {GROUP} (got {})",
            self.d_ff
        );
        ensure!(self.n_heads > 0, "n_heads must be positive");
        ensure!(
            self.d_model % self.n_heads == 0,
            "n_heads {} must divide d_model {}",
            self.n_heads,
            self.d_model
        );
        ensure!(
            self.head_dim() % 2 == 0,
            "rotary needs an even head dim (got {})",
            self.head_dim()
        );
        ensure!(self.n_layers > 0, "n_layers must be positive");
        ensure!(self.vocab > 1, "degenerate vocab");
        ensure!(self.seq > 0, "seq must be positive");
        Ok(())
    }

    /// The extra trainability constraint: the tied head's backward
    /// quantizes the logit gradient `[rows, vocab]`, so training (like
    /// the MLP's) needs an MX-group-aligned vocab.
    pub fn validate_for_training(&self) -> Result<()> {
        self.validate()?;
        ensure!(
            self.vocab % GROUP == 0,
            "training quantizes the logit gradient [rows, vocab], so vocab must be a \
             multiple of {GROUP} (got {})",
            self.vocab
        );
        Ok(())
    }

    /// Linear-layer parameter count (the scaling-law N; embeddings and
    /// norm gains excluded, matching the MLP convention).
    pub fn non_embedding_params(&self) -> usize {
        self.n_layers * (4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff)
    }
}

/// One decoder block: pre-norm attention + pre-norm SwiGLU MLP.
#[derive(Debug, Clone)]
pub struct TransformerBlock {
    /// RMSNorm gain before attention, `[d_model]`
    pub attn_norm: Vec<f32>,
    pub wq: QuantLinear,
    pub wk: QuantLinear,
    pub wv: QuantLinear,
    pub wo: QuantLinear,
    /// RMSNorm gain before the MLP, `[d_model]`
    pub mlp_norm: Vec<f32>,
    pub w_gate: QuantLinear,
    pub w_up: QuantLinear,
    pub w_down: QuantLinear,
}

impl TransformerBlock {
    fn init(d_model: usize, d_ff: usize, rng: &mut Rng) -> TransformerBlock {
        TransformerBlock {
            attn_norm: vec![1.0f32; d_model],
            wq: QuantLinear::init(d_model, d_model, rng),
            wk: QuantLinear::init(d_model, d_model, rng),
            wv: QuantLinear::init(d_model, d_model, rng),
            wo: QuantLinear::init(d_model, d_model, rng),
            mlp_norm: vec![1.0f32; d_model],
            w_gate: QuantLinear::init(d_ff, d_model, rng),
            w_up: QuantLinear::init(d_ff, d_model, rng),
            w_down: QuantLinear::init(d_model, d_ff, rng),
        }
    }
}

/// Per-tensor gradients, same slot layout as [`TransformerLm::param_sizes`].
pub struct TfGrads {
    pub tok_emb: Vec<f32>,
    pub blocks: Vec<TfBlockGrads>,
    pub final_norm: Vec<f32>,
}

pub struct TfBlockGrads {
    pub attn_norm: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Vec<f32>,
    pub w_up: Vec<f32>,
    pub w_down: Vec<f32>,
}

/// The native Llama-style language model.
#[derive(Debug, Clone)]
pub struct TransformerLm {
    pub cfg: TransformerConfig,
    /// `[vocab, d_model]` row-major; doubles as the tied vocab head
    pub tok_emb: Vec<f32>,
    pub blocks: Vec<TransformerBlock>,
    /// final RMSNorm gain, `[d_model]`
    pub final_norm: Vec<f32>,
}

/// Forward residue of one block the backward consumes.
struct BlockCache {
    /// residual-stream input `[R, D]`
    x_in: Vec<f32>,
    attn_inv: Vec<f32>,
    lq: LinearCache,
    lk: LinearCache,
    lv: LinearCache,
    /// post-rope q/k and raw v, head-split `[B·H, S, hd]`
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    probs: Vec<f32>,
    lo: LinearCache,
    /// residual stream after the attention add `[R, D]`
    x_mid: Vec<f32>,
    mlp_inv: Vec<f32>,
    lg: LinearCache,
    lu: LinearCache,
    gate: Vec<f32>,
    up: Vec<f32>,
    ld: LinearCache,
}

impl TransformerLm {
    pub fn init(cfg: TransformerConfig, seed: u64) -> Result<TransformerLm> {
        cfg.validate()?;
        let mut rng = Rng::new(seed);
        // 1/√d embedding init: the tied head dots a unit-RMS hidden row
        // (≈ √d L2 norm after the final RMSNorm) against embedding rows,
        // so unit-variance embeddings would put the initial logits at
        // std ≈ √d — loss ≫ ln(V) and an instant trip of the trainer's
        // divergence guard. Unit-norm rows keep init loss ≈ ln(V).
        let emb_scale = 1.0 / (cfg.d_model as f32).sqrt();
        let tok_emb = rng.gaussian_vec(cfg.vocab * cfg.d_model, emb_scale);
        let blocks = (0..cfg.n_layers)
            .map(|_| TransformerBlock::init(cfg.d_model, cfg.d_ff, &mut rng))
            .collect();
        let final_norm = vec![1.0f32; cfg.d_model];
        Ok(TransformerLm { cfg, tok_emb, blocks, final_norm })
    }

    /// Adam slot sizes: tok_emb, then per block (attn_norm, wq, wk, wv,
    /// wo, mlp_norm, w_gate, w_up, w_down), then final_norm — the order
    /// the trainer applies updates in.
    pub fn param_sizes(&self) -> Vec<usize> {
        let mut v = vec![self.tok_emb.len()];
        for b in &self.blocks {
            v.extend([
                b.attn_norm.len(),
                b.wq.w.len(),
                b.wk.w.len(),
                b.wv.w.len(),
                b.wo.w.len(),
                b.mlp_norm.len(),
                b.w_gate.w.len(),
                b.w_up.w.len(),
                b.w_down.w.len(),
            ]);
        }
        v.push(self.final_norm.len());
        v
    }

    /// Full forward over `tokens [b, s]`: returns (block caches, final
    /// residual stream, final-norm inv, tied-head linear cache, logits
    /// `[b·s, vocab]`).
    #[allow(clippy::type_complexity)]
    fn forward_full(
        &self,
        tokens: &[u32],
        b: usize,
        s: usize,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (Vec<BlockCache>, Vec<f32>, Vec<f32>, LinearCache, Vec<f32>) {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let vocab = self.cfg.vocab;
        let method = self.cfg.method;
        let rows = b * s;
        assert_eq!(tokens.len(), rows, "token batch shape");
        let scale = 1.0 / (hd as f32).sqrt();

        // embedding gather
        let mut x = vec![0.0f32; rows * d];
        for (r, &t) in tokens.iter().enumerate() {
            let src = (t as usize % vocab) * d;
            x[r * d..(r + 1) * d].copy_from_slice(&self.tok_emb[src..src + d]);
        }

        let mut caches = Vec::with_capacity(self.blocks.len());
        for block in &self.blocks {
            let x_in = x;
            let (a, attn_inv) = rmsnorm_rows(&x_in, &block.attn_norm, d);
            let (mut q, lq) = block.wq.forward(&a, rows, method, be, rng);
            let (mut k, lk) = block.wk.forward(&a, rows, method, be, rng);
            let (v, lv) = block.wv.forward(&a, rows, method, be, rng);
            for r in 0..rows {
                let pos = r % s;
                rope_row(&mut q[r * d..(r + 1) * d], h, hd, pos, false);
                rope_row(&mut k[r * d..(r + 1) * d], h, hd, pos, false);
            }
            let qh = split_heads(&q, b, s, h, hd);
            let kh = split_heads(&k, b, s, h, hd);
            let vh = split_heads(&v, b, s, h, hd);
            let (ctxh, probs) = be.attention_causal(&qh, &kh, &vh, b * h, s, s, hd, 0, scale);
            let ctx = merge_heads(&ctxh, b, s, h, hd);
            let (attn_out, lo) = block.wo.forward(&ctx, rows, method, be, rng);
            let mut x_mid = x_in.clone();
            add_assign(&mut x_mid, &attn_out);
            let (m, mlp_inv) = rmsnorm_rows(&x_mid, &block.mlp_norm, d);
            let (gate, lg) = block.w_gate.forward(&m, rows, method, be, rng);
            let (up, lu) = block.w_up.forward(&m, rows, method, be, rng);
            let hsw: Vec<f32> = gate.iter().zip(&up).map(|(&g0, &u0)| silu(g0) * u0).collect();
            let (down, ld) = block.w_down.forward(&hsw, rows, method, be, rng);
            let mut x_out = x_mid.clone();
            add_assign(&mut x_out, &down);
            caches.push(BlockCache {
                x_in,
                attn_inv,
                lq,
                lk,
                lv,
                qh,
                kh,
                vh,
                probs,
                lo,
                x_mid,
                mlp_inv,
                lg,
                lu,
                gate,
                up,
                ld,
            });
            x = x_out;
        }
        let (hn, final_inv) = rmsnorm_rows(&x, &self.final_norm, d);
        // tied head: logits = Q(hn)·Q(E)ᵀ under the method's precision.
        // The weight is the shared f32 embedding master, quantized on the
        // way into the GEMM like every other linear (the embedding
        // *lookup* stays f32 — only the head matmul sees the axis).
        let (logits, head) = forward_with(&self.tok_emb, vocab, d, &hn, rows, method, be, rng);
        (caches, x, final_inv, head, logits)
    }

    /// Inference logits `[b·s, vocab]` for `tokens [b, s]` (deterministic:
    /// every method's forward precision draws nothing from the RNG).
    pub fn logits(&self, tokens: &[u32], b: usize, s: usize, be: &dyn Backend) -> Vec<f32> {
        let mut rng = Rng::new(0);
        let (_, _, _, _, logits) = self.forward_full(tokens, b, s, be, &mut rng);
        logits
    }

    /// Mean next-token cross-entropy over `tokens [b, seq+1]` windows.
    pub fn eval_loss(&self, tokens: &[u32], b: usize, be: &dyn Backend) -> f64 {
        let s = self.cfg.seq;
        let (inputs, targets) = split_windows(tokens, b, s);
        let logits = self.logits(&inputs, b, s, be);
        let (loss, _) = softmax_xent(&logits, &targets, self.cfg.vocab, false);
        loss
    }

    /// One full forward/backward over `tokens [b, seq+1]` windows: the
    /// mean training loss and the gradients of every parameter tensor.
    pub fn loss_and_grads(
        &self,
        tokens: &[u32],
        b: usize,
        be: &dyn Backend,
        rng: &mut Rng,
    ) -> (f64, TfGrads) {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let d_ff = self.cfg.d_ff;
        let vocab = self.cfg.vocab;
        let method = self.cfg.method;
        let s = self.cfg.seq;
        let rows = b * s;
        let scale = 1.0 / (hd as f32).sqrt();

        let (inputs, targets) = split_windows(tokens, b, s);
        let (caches, x_final, final_inv, head, logits) =
            self.forward_full(&inputs, b, s, be, rng);
        let (loss, dlogits) = softmax_xent(&logits, &targets, vocab, true);
        let dlogits = dlogits.expect("grad requested");

        // tied head backward under the method: the raw logit gradient —
        // the model's most heavy-tailed tensor — passes through the
        // method's gradient quantizer here, exactly like the MLP's vocab
        // projection (this is where naive RTN's bias costs whole nats)
        let (dhn, mut de) =
            backward_with(&self.tok_emb, vocab, d, &dlogits, &head, rows, method, be, rng);

        let (mut dx, final_norm_grad) =
            rmsnorm_backward(&dhn, &x_final, &self.final_norm, &final_inv, d);

        // walked in reverse block order, reversed once at the end
        let mut block_grads: Vec<TfBlockGrads> = Vec::with_capacity(self.blocks.len());
        for li in (0..self.blocks.len()).rev() {
            let block = &self.blocks[li];
            let c = &caches[li];
            // ---- MLP branch (dx is the gradient wrt x_out) -------------
            let (dh, dwd) = block.w_down.backward(&dx, &c.ld, rows, method, be, rng);
            let mut dgate = vec![0.0f32; rows * d_ff];
            let mut dup = vec![0.0f32; rows * d_ff];
            for i in 0..rows * d_ff {
                let g0 = c.gate[i];
                let sg = sigmoid(g0);
                dgate[i] = dh[i] * c.up[i] * (sg * (1.0 + g0 * (1.0 - sg)));
                dup[i] = dh[i] * (g0 * sg);
            }
            let (dm1, dwg) = block.w_gate.backward(&dgate, &c.lg, rows, method, be, rng);
            let (dm2, dwu) = block.w_up.backward(&dup, &c.lu, rows, method, be, rng);
            let mut dm = dm1;
            add_assign(&mut dm, &dm2);
            let (dxm, dgm) = rmsnorm_backward(&dm, &c.x_mid, &block.mlp_norm, &c.mlp_inv, d);
            // residual: gradient wrt x_mid = skip path + norm path
            add_assign(&mut dx, &dxm);
            // ---- attention branch (dx is now the gradient wrt x_mid) ---
            let (dctx, dwo) = block.wo.backward(&dx, &c.lo, rows, method, be, rng);
            let dctxh = split_heads(&dctx, b, s, h, hd);
            let (dqh, dkh, dvh) = attention_backward(
                &c.qh, &c.kh, &c.vh, &c.probs, &dctxh, b * h, s, s, hd, 0, scale,
            );
            let mut dq = merge_heads(&dqh, b, s, h, hd);
            let mut dk = merge_heads(&dkh, b, s, h, hd);
            let dv = merge_heads(&dvh, b, s, h, hd);
            for r in 0..rows {
                let pos = r % s;
                rope_row(&mut dq[r * d..(r + 1) * d], h, hd, pos, true);
                rope_row(&mut dk[r * d..(r + 1) * d], h, hd, pos, true);
            }
            let (da1, dwq) = block.wq.backward(&dq, &c.lq, rows, method, be, rng);
            let (da2, dwk) = block.wk.backward(&dk, &c.lk, rows, method, be, rng);
            let (da3, dwv) = block.wv.backward(&dv, &c.lv, rows, method, be, rng);
            let mut da = da1;
            add_assign(&mut da, &da2);
            add_assign(&mut da, &da3);
            let (dxa, dga) = rmsnorm_backward(&da, &c.x_in, &block.attn_norm, &c.attn_inv, d);
            add_assign(&mut dx, &dxa);
            block_grads.push(TfBlockGrads {
                attn_norm: dga,
                wq: dwq,
                wk: dwk,
                wv: dwv,
                wo: dwo,
                mlp_norm: dgm,
                w_gate: dwg,
                w_up: dwu,
                w_down: dwd,
            });
        }
        block_grads.reverse();
        // embedding gather backward (the head leg is already in `de`)
        for (r, &t) in inputs.iter().enumerate() {
            let dst = (t as usize % vocab) * d;
            for j in 0..d {
                de[dst + j] += dx[r * d + j];
            }
        }
        (loss, TfGrads { tok_emb: de, blocks: block_grads, final_norm: final_norm_grad })
    }

    // ---- checkpointing ----------------------------------------------------

    /// Write the checkpoint JSON (`kind: "native-llama-lm"`).
    pub fn save(&self, path: &Path) -> Result<()> {
        let c = &self.cfg;
        let blocks = self.blocks.iter().map(|b| {
            Json::from_pairs(vec![
                ("attn_norm", Json::f32s(&b.attn_norm)),
                ("wq", Json::f32s(&b.wq.w)),
                ("wk", Json::f32s(&b.wk.w)),
                ("wv", Json::f32s(&b.wv.w)),
                ("wo", Json::f32s(&b.wo.w)),
                ("mlp_norm", Json::f32s(&b.mlp_norm)),
                ("w_gate", Json::f32s(&b.w_gate.w)),
                ("w_up", Json::f32s(&b.w_up.w)),
                ("w_down", Json::f32s(&b.w_down.w)),
            ])
        });
        let j = Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            ("kind", Json::str("native-llama-lm")),
            ("method", Json::str(c.method.name())),
            ("vocab", Json::num(c.vocab as f64)),
            ("d_model", Json::num(c.d_model as f64)),
            ("n_heads", Json::num(c.n_heads as f64)),
            ("n_layers", Json::num(c.n_layers as f64)),
            ("d_ff", Json::num(c.d_ff as f64)),
            ("seq", Json::num(c.seq as f64)),
            ("tok_emb", Json::f32s(&self.tok_emb)),
            ("final_norm", Json::f32s(&self.final_norm)),
            ("blocks", Json::array(blocks)),
        ]);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, j.to_string())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Load and shape-check a checkpoint written by [`TransformerLm::save`].
    pub fn load(path: &Path) -> Result<TransformerLm> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("loading {}", path.display()))
    }

    /// Build from already-parsed checkpoint JSON (weight dumps are large;
    /// `NativeModel::load` parses once and dispatches here by `kind`).
    pub fn from_json(j: &Json) -> Result<TransformerLm> {
        let kind = j.req("kind")?.as_str().unwrap_or("");
        if kind != "native-llama-lm" {
            bail!("not a transformer checkpoint (kind {kind:?})");
        }
        let cfg = TransformerConfig {
            vocab: j.req("vocab")?.as_usize().unwrap_or(0),
            d_model: j.req("d_model")?.as_usize().unwrap_or(0),
            n_heads: j.req("n_heads")?.as_usize().unwrap_or(0),
            n_layers: j.req("n_layers")?.as_usize().unwrap_or(0),
            d_ff: j.req("d_ff")?.as_usize().unwrap_or(0),
            seq: j.req("seq")?.as_usize().unwrap_or(0),
            method: TrainMethod::parse(j.req("method")?.as_str().unwrap_or(""))?,
        };
        cfg.validate()?;
        let f32s = |v: &Json, what: &str, want: usize| -> Result<Vec<f32>> {
            let out: Vec<f32> = v
                .as_arr()
                .ok_or_else(|| anyhow!("{what} not an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow!("{what}: non-numeric entry"))
                })
                .collect::<Result<_>>()?;
            if out.len() != want {
                bail!("{what} has {} values, config wants {want}", out.len());
            }
            Ok(out)
        };
        let dim2 = |a: usize, b: usize, what: &str| -> Result<usize> {
            a.checked_mul(b).ok_or_else(|| {
                anyhow!("{what} shape {a}x{b} overflows usize — corrupt or hostile dims")
            })
        };
        let d = cfg.d_model;
        let dd = dim2(d, d, "attention weight")?;
        let ffd = dim2(cfg.d_ff, d, "mlp weight")?;
        let tok_emb = f32s(j.req("tok_emb")?, "tok_emb", dim2(cfg.vocab, d, "tok_emb")?)?;
        let final_norm = f32s(j.req("final_norm")?, "final_norm", d)?;
        let raw = j
            .req("blocks")?
            .as_arr()
            .ok_or_else(|| anyhow!("blocks not an array"))?;
        if raw.len() != cfg.n_layers {
            bail!("checkpoint has {} blocks, config wants {}", raw.len(), cfg.n_layers);
        }
        let mut blocks = Vec::with_capacity(raw.len());
        for (li, bj) in raw.iter().enumerate() {
            let ctx = |f: &str| format!("block {li} {f}");
            blocks.push(TransformerBlock {
                attn_norm: f32s(bj.req("attn_norm")?, &ctx("attn_norm"), d)?,
                wq: QuantLinear::from_weights(d, d, f32s(bj.req("wq")?, &ctx("wq"), dd)?),
                wk: QuantLinear::from_weights(d, d, f32s(bj.req("wk")?, &ctx("wk"), dd)?),
                wv: QuantLinear::from_weights(d, d, f32s(bj.req("wv")?, &ctx("wv"), dd)?),
                wo: QuantLinear::from_weights(d, d, f32s(bj.req("wo")?, &ctx("wo"), dd)?),
                mlp_norm: f32s(bj.req("mlp_norm")?, &ctx("mlp_norm"), d)?,
                w_gate: QuantLinear::from_weights(
                    cfg.d_ff,
                    d,
                    f32s(bj.req("w_gate")?, &ctx("w_gate"), ffd)?,
                ),
                w_up: QuantLinear::from_weights(
                    cfg.d_ff,
                    d,
                    f32s(bj.req("w_up")?, &ctx("w_up"), ffd)?,
                ),
                w_down: QuantLinear::from_weights(
                    d,
                    cfg.d_ff,
                    f32s(bj.req("w_down")?, &ctx("w_down"), ffd)?,
                ),
            });
        }
        Ok(TransformerLm { cfg, tok_emb, blocks, final_norm })
    }
}

/// Split `[b, s+1]` token windows into inputs `[b, s]` and next-token
/// targets `[b, s]`.
pub(crate) fn split_windows(tokens: &[u32], b: usize, s: usize) -> (Vec<u32>, Vec<u32>) {
    assert_eq!(tokens.len(), b * (s + 1), "window batch shape");
    let mut inputs = Vec::with_capacity(b * s);
    let mut targets = Vec::with_capacity(b * s);
    for bi in 0..b {
        let w = &tokens[bi * (s + 1)..(bi + 1) * (s + 1)];
        inputs.extend_from_slice(&w[..s]);
        targets.extend_from_slice(&w[1..]);
    }
    (inputs, targets)
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

pub(crate) fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// RMSNorm over each `[d]` row: `y = g ⊙ x · rsqrt(mean(x²) + ε)`; the
/// mean square accumulates in f64 (row-local, so the serving KV path stays
/// batch-composition independent). Returns `(y, inv per row)`.
pub(crate) fn rmsnorm_rows(x: &[f32], g: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len() % d, 0);
    assert_eq!(g.len(), d);
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    let mut invs = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut ms = 0.0f64;
        for &v in xr {
            ms += (v as f64) * (v as f64);
        }
        let inv = (1.0 / (ms / d as f64 + RMS_EPS).sqrt()) as f32;
        invs[r] = inv;
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            yr[j] = g[j] * xr[j] * inv;
        }
    }
    (y, invs)
}

/// Backward of [`rmsnorm_rows`]: returns `(dx, dg)`.
pub(crate) fn rmsnorm_backward(
    dy: &[f32],
    x: &[f32],
    g: &[f32],
    inv: &[f32],
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / d;
    assert_eq!(dy.len(), x.len());
    assert_eq!(inv.len(), rows);
    let mut dx = vec![0.0f32; x.len()];
    let mut dg = vec![0.0f32; d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let rin = inv[r];
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += (g[j] * dyr[j] * xr[j]) as f64;
        }
        let coef = ((rin as f64).powi(3) * dot / d as f64) as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = rin * g[j] * dyr[j] - coef * xr[j];
            dg[j] += dyr[j] * xr[j] * rin;
        }
    }
    (dx, dg)
}

/// Rotary cos/sin for pair `i` of a head at position `pos`.
#[inline]
fn rope_cos_sin(pos: usize, i: usize, hd: usize) -> (f32, f32) {
    let freq = ROPE_THETA.powf(-((2 * i) as f32) / hd as f32);
    let angle = pos as f32 * freq;
    (angle.cos(), angle.sin())
}

/// Apply the rotary rotation to every head of one `[n_heads·hd]` row at
/// `pos` (adjacent pairs within each head); `inv` applies the transpose
/// rotation — the exact backward. The (cos, sin) pair depends only on
/// (pos, pair index), so it is computed once per pair and reused across
/// heads — n_heads× fewer transcendental calls on the decode hot loop,
/// bit-identical output.
pub(crate) fn rope_row(row: &mut [f32], n_heads: usize, hd: usize, pos: usize, inv: bool) {
    debug_assert_eq!(row.len(), n_heads * hd);
    for i in 0..hd / 2 {
        let (c, s0) = rope_cos_sin(pos, i, hd);
        let s = if inv { -s0 } else { s0 };
        for h in 0..n_heads {
            let base = h * hd + 2 * i;
            let a = row[base];
            let b = row[base + 1];
            row[base] = a * c - b * s;
            row[base + 1] = a * s + b * c;
        }
    }
}

/// `[b·s, h·hd]` row-major → head-split `[b·h, s, hd]`.
pub(crate) fn split_heads(x: &[f32], b: usize, s: usize, h: usize, hd: usize) -> Vec<f32> {
    let d = h * hd;
    assert_eq!(x.len(), b * s * d);
    let mut out = vec![0.0f32; x.len()];
    for bi in 0..b {
        for hi in 0..h {
            for si in 0..s {
                let src = (bi * s + si) * d + hi * hd;
                let dst = ((bi * h + hi) * s + si) * hd;
                out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
            }
        }
    }
    out
}

/// Head-split `[b·h, s, hd]` → `[b·s, h·hd]` row-major.
pub(crate) fn merge_heads(x: &[f32], b: usize, s: usize, h: usize, hd: usize) -> Vec<f32> {
    let d = h * hd;
    assert_eq!(x.len(), b * s * d);
    let mut out = vec![0.0f32; x.len()];
    for bi in 0..b {
        for hi in 0..h {
            for si in 0..s {
                let src = ((bi * h + hi) * s + si) * hd;
                let dst = (bi * s + si) * d + hi * hd;
                out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
            }
        }
    }
    out
}

/// Backward of [`Backend::attention_causal`] (training only — runs the
/// scalar loops; the quantized linears dominate the step cost).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dctx: &[f32],
    groups: usize,
    sq: usize,
    sk: usize,
    hd: usize,
    pos0: usize,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), groups * sq * hd);
    assert_eq!(k.len(), groups * sk * hd);
    assert_eq!(v.len(), groups * sk * hd);
    assert_eq!(probs.len(), groups * sq * sk);
    assert_eq!(dctx.len(), groups * sq * hd);
    let mut dq = vec![0.0f32; groups * sq * hd];
    let mut dk = vec![0.0f32; groups * sk * hd];
    let mut dv = vec![0.0f32; groups * sk * hd];
    let mut dp = vec![0.0f32; sk];
    for g in 0..groups {
        for i in 0..sq {
            let limit = pos0 + i + 1;
            let prow = &probs[(g * sq + i) * sk..(g * sq + i + 1) * sk];
            let dcrow = &dctx[(g * sq + i) * hd..(g * sq + i + 1) * hd];
            let mut dot_pd = 0.0f64;
            for j in 0..limit {
                let vj = &v[(g * sk + j) * hd..(g * sk + j + 1) * hd];
                let d0 = dot_f32(dcrow, vj);
                dp[j] = d0;
                dot_pd += (prow[j] * d0) as f64;
                let dvj = &mut dv[(g * sk + j) * hd..(g * sk + j + 1) * hd];
                for dd in 0..hd {
                    dvj[dd] += prow[j] * dcrow[dd];
                }
            }
            let qi = &q[(g * sq + i) * hd..(g * sq + i + 1) * hd];
            for j in 0..limit {
                let ds = prow[j] * (dp[j] - dot_pd as f32) * scale;
                let kj = &k[(g * sk + j) * hd..(g * sk + j + 1) * hd];
                let dqi = g * sq * hd + i * hd;
                let dkj = g * sk * hd + j * hd;
                for dd in 0..hd {
                    dq[dqi + dd] += ds * kj[dd];
                    dk[dkj + dd] += ds * qi[dd];
                }
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    fn tiny_cfg(method: TrainMethod) -> TransformerConfig {
        TransformerConfig {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            seq: 4,
            method,
        }
    }

    fn windows(b: usize, s: usize, vocab: u32, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..b * (s + 1)).map(|_| rng.below(vocab as usize) as u32).collect()
    }

    #[test]
    fn config_validation_catches_misalignment() {
        let ok = tiny_cfg(TrainMethod::Quartet);
        ok.validate().unwrap();
        assert!(TransformerConfig { d_model: 48, ..ok.clone() }.validate().is_err());
        assert!(TransformerConfig { d_ff: 40, ..ok.clone() }.validate().is_err());
        assert!(TransformerConfig { n_heads: 3, ..ok.clone() }.validate().is_err());
        assert!(TransformerConfig { n_heads: 0, ..ok.clone() }.validate().is_err());
        assert!(TransformerConfig { n_layers: 0, ..ok.clone() }.validate().is_err());
        // odd vocab serves fine (the head contracts over d_model)...
        let odd_vocab = TransformerConfig { vocab: 100, ..ok.clone() };
        odd_vocab.validate().unwrap();
        // ...but is not trainable: the head backward quantizes dlogits
        assert!(odd_vocab.validate_for_training().is_err());
        ok.validate_for_training().unwrap();
        assert_eq!(ok.non_embedding_params(), 4 * 32 * 32 + 3 * 32 * 32);
    }

    #[test]
    fn init_loss_near_log_vocab() {
        for method in TrainMethod::ALL {
            let m = TransformerLm::init(tiny_cfg(method), 1).unwrap();
            let toks = windows(8, 4, 32, 2);
            let loss = m.eval_loss(&toks, 8, &ScalarBackend);
            let expect = (32f64).ln();
            assert!(
                (loss - expect).abs() < 1.3,
                "{}: init loss {loss} vs ln(V) {expect}",
                method.name()
            );
        }
    }

    #[test]
    fn logits_are_causal() {
        // changing the last token must not move any earlier position's row
        let m = TransformerLm::init(tiny_cfg(TrainMethod::Quartet), 3).unwrap();
        let s = 6usize;
        let a: Vec<u32> = (0..s as u32).map(|i| (i * 5 + 1) % 32).collect();
        let mut b = a.clone();
        b[s - 1] = (b[s - 1] + 7) % 32;
        let la = m.logits(&a, 1, s, &ScalarBackend);
        let lb = m.logits(&b, 1, s, &ScalarBackend);
        assert_eq!(la[..(s - 1) * 32], lb[..(s - 1) * 32], "future token leaked");
        assert_ne!(la[(s - 1) * 32..], lb[(s - 1) * 32..], "last position ignores its input");
    }

    #[test]
    fn grads_have_param_shapes() {
        let m = TransformerLm::init(tiny_cfg(TrainMethod::Quartet), 5).unwrap();
        let toks = windows(4, 4, 32, 6);
        let (loss, g) = m.loss_and_grads(&toks, 4, &ScalarBackend, &mut Rng::new(7));
        assert!(loss.is_finite());
        assert_eq!(g.tok_emb.len(), m.tok_emb.len());
        assert_eq!(g.final_norm.len(), m.final_norm.len());
        assert_eq!(g.blocks.len(), 1);
        let b = &g.blocks[0];
        assert_eq!(b.wq.len(), m.blocks[0].wq.w.len());
        assert_eq!(b.w_gate.len(), m.blocks[0].w_gate.w.len());
        assert_eq!(b.attn_norm.len(), 32);
    }

    /// f32 backward must match the numerical gradient of the actual
    /// training loss — pins attention/rope/rmsnorm/SwiGLU backward
    /// plumbing end to end.
    #[test]
    fn f32_backward_matches_finite_difference() {
        let be = ScalarBackend;
        let m = TransformerLm::init(tiny_cfg(TrainMethod::F32), 11).unwrap();
        let toks = windows(2, 4, 32, 12);
        let (_, g) = m.loss_and_grads(&toks, 2, &be, &mut Rng::new(0));
        let eps = 2e-2f32;
        let check = |get: &dyn Fn(&TransformerLm) -> &Vec<f32>,
                     set: &dyn Fn(&mut TransformerLm, usize, f32),
                     grad: &[f32],
                     idx: usize,
                     what: &str| {
            let base = get(&m)[idx];
            let mut mp = m.clone();
            set(&mut mp, idx, base + eps);
            let mut mm = m.clone();
            set(&mut mm, idx, base - eps);
            let lp = mp.eval_loss(&toks, 2, &be);
            let lm = mm.eval_loss(&toks, 2, &be);
            let num = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (num - grad[idx] as f64).abs() < 2e-2 * (1.0 + num.abs()),
                "{what}[{idx}]: numeric {num} vs analytic {}",
                grad[idx]
            );
        };
        check(
            &|m| &m.blocks[0].wq.w,
            &|m, i, v| m.blocks[0].wq.w[i] = v,
            &g.blocks[0].wq,
            17,
            "wq",
        );
        check(
            &|m| &m.blocks[0].wo.w,
            &|m, i, v| m.blocks[0].wo.w[i] = v,
            &g.blocks[0].wo,
            41,
            "wo",
        );
        check(
            &|m| &m.blocks[0].w_gate.w,
            &|m, i, v| m.blocks[0].w_gate.w[i] = v,
            &g.blocks[0].w_gate,
            5,
            "w_gate",
        );
        check(
            &|m| &m.blocks[0].w_down.w,
            &|m, i, v| m.blocks[0].w_down.w[i] = v,
            &g.blocks[0].w_down,
            99,
            "w_down",
        );
        check(
            &|m| &m.blocks[0].attn_norm,
            &|m, i, v| m.blocks[0].attn_norm[i] = v,
            &g.blocks[0].attn_norm,
            3,
            "attn_norm",
        );
        check(
            &|m| &m.final_norm,
            &|m, i, v| m.final_norm[i] = v,
            &g.final_norm,
            9,
            "final_norm",
        );
        check(&|m| &m.tok_emb, &|m, i, v| m.tok_emb[i] = v, &g.tok_emb, 65, "tok_emb");
    }

    #[test]
    fn rope_roundtrips_and_preserves_norm() {
        let mut rng = Rng::new(4);
        let (h, hd) = (2usize, 16usize);
        let x = rng.gaussian_vec(h * hd, 1.0);
        let mut y = x.clone();
        rope_row(&mut y, h, hd, 13, false);
        let n0: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let n1: f64 = y.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((n0 - n1).abs() < 1e-3 * (1.0 + n0), "rotation changed the norm");
        rope_row(&mut y, h, hd, 13, true);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // position 0 is the identity
        let mut z = x.clone();
        rope_row(&mut z, h, hd, 0, false);
        assert_eq!(z, x);
    }

    #[test]
    fn split_merge_heads_roundtrip() {
        let mut rng = Rng::new(5);
        let (b, s, h, hd) = (2usize, 3usize, 2usize, 4usize);
        let x = rng.gaussian_vec(b * s * h * hd, 1.0);
        let sp = split_heads(&x, b, s, h, hd);
        assert_eq!(merge_heads(&sp, b, s, h, hd), x);
        // spot-check one element: batch 1, pos 2, head 1, dim 3
        let d = h * hd;
        assert_eq!(sp[((h + 1) * s + 2) * hd + 3], x[(s + 2) * d + hd + 3]);
    }

    #[test]
    fn checkpoint_roundtrip_bit_exact() {
        let m = TransformerLm::init(tiny_cfg(TrainMethod::Mxfp8), 9).unwrap();
        let path = std::env::temp_dir()
            .join(format!("native_tf_ckpt_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let back = TransformerLm::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(back.cfg.vocab, m.cfg.vocab);
        assert_eq!(back.cfg.n_heads, m.cfg.n_heads);
        assert_eq!(back.cfg.method, m.cfg.method);
        assert_eq!(back.tok_emb, m.tok_emb);
        assert_eq!(back.final_norm, m.final_norm);
        for (a, b) in back.blocks.iter().zip(&m.blocks) {
            assert_eq!(a.wq.w, b.wq.w);
            assert_eq!(a.w_down.w, b.w_down.w);
            assert_eq!(a.attn_norm, b.attn_norm);
            assert_eq!(a.mlp_norm, b.mlp_norm);
        }
    }

    #[test]
    fn load_rejects_mlp_checkpoints_and_shape_lies() {
        let m = TransformerLm::init(tiny_cfg(TrainMethod::F32), 13).unwrap();
        let path = std::env::temp_dir()
            .join(format!("native_tf_bad_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let bad = text.replace("\"d_ff\":32", "\"d_ff\":64");
        std::fs::write(&path, bad).unwrap();
        assert!(TransformerLm::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
        // an MLP checkpoint must be rejected by kind, loudly
        let mlp = crate::train::MlpLm::init(
            crate::train::ModelConfig {
                vocab: 32,
                d_emb: 16,
                d_hidden: 64,
                n_hidden: 0,
                method: TrainMethod::F32,
            },
            1,
        )
        .unwrap();
        let path2 = std::env::temp_dir()
            .join(format!("native_tf_mlp_{}.json", std::process::id()));
        mlp.save(&path2).unwrap();
        assert!(TransformerLm::load(&path2).is_err());
        std::fs::remove_file(&path2).unwrap();
    }

    #[test]
    fn load_rejects_overflowing_dims() {
        // vocab * d_model == 2^64: the hostile header must die in
        // checked_mul, never wrap to a small "expected" length
        let m = TransformerLm::init(tiny_cfg(TrainMethod::F32), 21).unwrap();
        let path = std::env::temp_dir()
            .join(format!("native_tf_overflow_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let huge = (1u64 << 59).to_string();
        let bad = text.replace("\"vocab\":32", &format!("\"vocab\":{huge}"));
        assert_ne!(bad, text, "fixture vocab moved; update the replace");
        std::fs::write(&path, bad).unwrap();
        let err = format!("{:#}", TransformerLm::load(&path).unwrap_err());
        std::fs::remove_file(&path).unwrap();
        assert!(err.contains("overflows"), "got: {err}");
    }
}
