//! Tensor- and pipeline-parallel training over the MXFP4 wire format.
//!
//! [`dist`](crate::train::dist) gave the trainer a data-parallel axis:
//! logical gradient shards, physical workers, and a [`GradReducer`] whose
//! loss bits are a pure function of the *logical* configuration. This
//! module extends the same discipline to the other two axes of a 3D
//! topology, [`Topology`] `{ts, tp, pp, wire}`:
//!
//! * **Tensor sharding** (`ts`, logical) — every block matmul splits
//!   Megatron-style: `wq/wk/wv` and `w_gate/w_up` column-parallel (weight
//!   *rows*, since weights are `[d_out, d_in]` row-major), `wo/w_down`
//!   row-parallel (weight *columns*). Attention is slice-local per head
//!   group; SwiGLU is slice-local per `d_ff` range. Partial outputs meet
//!   in four all-reduce sites per block (fwd `wo`/`w_down` partials, bwd
//!   `da`/`dm` partials), each modeled as reduce-scatter + all-gather
//!   through [`Backend::reduce_scatter_mxfp4`] /
//!   [`Backend::all_gather_mxfp4`] when `wire = mxfp4`.
//! * **TP ranks** (`tp`, physical) — how many threads evaluate the `ts`
//!   slices; clamped to `ts`, never touches the bits.
//! * **Pipeline stages** (`pp`, physical) — contiguous block ranges run
//!   1F1B over the gradient shards as microbatches. Activations and
//!   backward gradients are pushed through the wire format at *every*
//!   interior block boundary regardless of `pp`, so stage placement is
//!   free to change without changing the loss.
//!
//! The dist invariant therefore generalizes: loss curves are bit-identical
//! at any `(workers, tp, pp)` placement of a fixed logical configuration
//! `(seed, shards, ts, wire)`. All SR draws are keyed by
//! `fold_salt(seed, step, shard, site-label)` — never by thread or
//! stage identity — with site labels offset by [`TOPO_SALT_OFFSET`] so
//! they cannot collide with the [`GradReducer`] tensor ids.
//!
//! Comms accounting is analytic (the topology determines it exactly):
//! per block and microbatch, each TP all-reduce moves
//! `(tp−1)·payload` bytes in its reduce-scatter and again in its
//! all-gather; each physical stage boundary moves one activation forward
//! and one gradient backward (`p2p`); the DP gradient ring is unchanged.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{ensure, Result};

use crate::kernels::Backend;
use crate::train::dist::{
    fold_salt, ring_allreduce_bytes, run_sharded, CommsBytes, DistOptions, GradReducer,
    ReduceMode, Topology,
};
use crate::train::layer::{backward_with, forward_with, LinearCache};
use crate::train::model::{relu, softmax_xent, Grads, MlpLm};
use crate::train::transformer::{
    add_assign, attention_backward, merge_heads, rmsnorm_backward, rmsnorm_rows, rope_row,
    sigmoid, silu, split_heads, split_windows, TfBlockGrads, TfGrads, TransformerConfig,
    TransformerLm,
};
use crate::train::{ModelConfig, TrainMethod};
use crate::util::rng::Rng;

use super::GROUP;

/// Offset of every topology SR-stream label, far above the
/// `1 + 9·n_layers + 1` tensor ids the [`GradReducer`] uses for the DP
/// reduction, so the two label spaces can never alias.
pub const TOPO_SALT_OFFSET: u64 = 0x1000_0000;

/// Site labels within one block (< [`SITE_STRIDE`]).
const SITE_FWD_O: u64 = 0;
const SITE_FWD_DOWN: u64 = 1;
const SITE_BWD_DA: u64 = 2;
const SITE_BWD_DM: u64 = 3;
const SITE_FWD_BOUNDARY: u64 = 4;
const SITE_BWD_BOUNDARY: u64 = 5;
const SITE_ATTN_STREAM: u64 = 6;
const SITE_MLP_STREAM: u64 = 7;
const SITE_HEAD_STREAM: u64 = 8;
// MLP-architecture sites (block label = layer index)
const SITE_MLP_FWD_AG: u64 = 9;
const SITE_MLP_BWD_AR: u64 = 10;
const SITE_MLP_LAYER_STREAM: u64 = 11;
const SITE_MLP_OUT_STREAM: u64 = 12;

const SITE_STRIDE: u64 = 16;
const SLICE_STRIDE: u64 = 4096;

/// Derive the i-th sub-salt of a collective site (one fresh stream per
/// `(participant, chunk)` pair, splitmix-spaced off the site base).
fn sub_salt(base: u64, i: u64) -> u64 {
    base.wrapping_add((i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

// ---- validation ----------------------------------------------------------

/// Shape constraints the transformer imposes on a topology: head groups
/// must tile the heads, and every slice-local contraction axis must still
/// tile into MX groups (the slices quantize independently).
pub fn validate_topo_transformer(cfg: &TransformerConfig, t: &Topology) -> Result<()> {
    t.validate()?;
    ensure!(
        t.pp <= cfg.n_layers,
        "pp {} exceeds the {} transformer blocks available",
        t.pp,
        cfg.n_layers
    );
    if t.ts > 1 {
        ensure!(
            cfg.n_heads % t.ts == 0,
            "ts {} must divide n_heads {} (attention shards by head groups)",
            t.ts,
            cfg.n_heads
        );
        ensure!(
            (cfg.d_model / t.ts) % GROUP == 0,
            "d_model/ts = {}/{} must stay a multiple of {GROUP} (slices quantize \
             their own contraction axis)",
            cfg.d_model,
            t.ts
        );
        ensure!(
            cfg.d_ff % t.ts == 0 && (cfg.d_ff / t.ts) % GROUP == 0,
            "d_ff/ts = {}/{} must stay a multiple of {GROUP}",
            cfg.d_ff,
            t.ts
        );
    }
    Ok(())
}

/// Shape constraints the MLP stack imposes: only the hidden layers shard
/// (the vocab projection stays replicated), and there is no block
/// structure to pipeline over.
pub fn validate_topo_mlp(cfg: &ModelConfig, t: &Topology) -> Result<()> {
    t.validate()?;
    ensure!(
        t.pp == 1,
        "pipeline parallelism needs the transformer's block structure; the MLP \
         stack supports the tensor axis only (pp {})",
        t.pp
    );
    if t.ts > 1 {
        ensure!(
            cfg.d_hidden % t.ts == 0 && (cfg.d_hidden / t.ts) % GROUP == 0,
            "d_hidden/ts = {}/{} must stay a multiple of {GROUP}",
            cfg.d_hidden,
            t.ts
        );
    }
    Ok(())
}

// ---- TP slicing helpers --------------------------------------------------

/// Contiguous row range `[r0, r1)` of a row-major `[rows, width]` matrix.
fn row_slice(w: &[f32], width: usize, r0: usize, r1: usize) -> Vec<f32> {
    w[r0 * width..r1 * width].to_vec()
}

/// Column range `[c0, c1)` of a row-major `[rows, width]` matrix as a
/// dense `[rows, c1-c0]` copy.
fn col_slice(w: &[f32], rows: usize, width: usize, c0: usize, c1: usize) -> Vec<f32> {
    let ww = c1 - c0;
    let mut out = Vec::with_capacity(rows * ww);
    for r in 0..rows {
        out.extend_from_slice(&w[r * width + c0..r * width + c1]);
    }
    out
}

/// Scatter a dense `[rows, w_src]` block back into columns `[c0, c0+w_src)`
/// of a row-major matrix with row width `width`.
fn col_scatter(dst: &mut [f32], width: usize, c0: usize, src: &[f32], w_src: usize) {
    let rows = src.len() / w_src;
    for r in 0..rows {
        dst[r * width + c0..r * width + c0 + w_src]
            .copy_from_slice(&src[r * w_src..(r + 1) * w_src]);
    }
}

/// Balanced contiguous block ranges for `pp` pipeline stages.
fn stage_ranges(n_blocks: usize, pp: usize) -> Vec<(usize, usize)> {
    let p = pp.clamp(1, n_blocks.max(1));
    let per = n_blocks / p;
    let rem = n_blocks % p;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0;
    for i in 0..p {
        let n = per + usize::from(i < rem);
        out.push((lo, lo + n));
        lo += n;
    }
    out
}

// ---- the shared wire machinery -------------------------------------------

/// Everything a shard's topology-aware step needs besides the model:
/// backend, logical axes, and the salt keys. `tp` is the *effective*
/// physical rank count (already clamped to `ts`).
struct TopoCtx<'a> {
    be: &'a dyn Backend,
    ts: usize,
    tp: usize,
    wire: ReduceMode,
    seed: u64,
    step: u64,
}

impl TopoCtx<'_> {
    /// Salt of one SR stream, keyed purely by logical identity:
    /// `(seed, step, shard)` plus a `(block, site, slice)` label.
    fn site_salt(&self, shard: u64, block: u64, site: u64, slice: u64) -> u64 {
        debug_assert!(site < SITE_STRIDE && slice < SLICE_STRIDE);
        fold_salt(
            self.seed,
            self.step,
            shard,
            TOPO_SALT_OFFSET + (block * SITE_STRIDE + site) * SLICE_STRIDE + slice,
        )
    }

    /// All-reduce `ts` partial `[rows, cols]` tensors at a TP meeting
    /// point. `f32` wire sums exactly in slice order; `mxfp4` wire models
    /// ring reduce-scatter (every partial crosses the wire per chunk) then
    /// all-gather (every summed chunk crosses again, fresh streams).
    fn wire_allreduce(
        &self,
        shard: u64,
        block: u64,
        site: u64,
        parts: Vec<Vec<f32>>,
        rows: usize,
        cols: usize,
    ) -> Vec<f32> {
        if parts.len() == 1 {
            return parts.into_iter().next().unwrap();
        }
        match self.wire {
            ReduceMode::F32 => {
                let mut it = parts.into_iter();
                let mut acc = it.next().unwrap();
                for p in it {
                    add_assign(&mut acc, &p);
                }
                acc
            }
            ReduceMode::Mxfp4 => {
                let base = self.site_salt(shard, block, site, 0);
                let chunks = self.ts;
                let n_parts = parts.len();
                let rs_salts: Vec<u64> =
                    (0..n_parts * chunks).map(|i| sub_salt(base, i as u64)).collect();
                let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
                let sum = self.be.reduce_scatter_mxfp4(&refs, rows, cols, chunks, &rs_salts);
                let mut chunk_refs: Vec<&[f32]> = Vec::with_capacity(chunks);
                let mut r0 = 0;
                for c in 0..chunks {
                    let n = rows / chunks + usize::from(c < rows % chunks);
                    chunk_refs.push(&sum[r0 * cols..(r0 + n) * cols]);
                    r0 += n;
                }
                let ag_salts: Vec<u64> = (0..chunks)
                    .map(|c| sub_salt(base, (n_parts * chunks + c) as u64))
                    .collect();
                self.be.all_gather_mxfp4(&chunk_refs, cols, &ag_salts)
            }
        }
    }

    /// Push a tensor through the wire format at a block boundary (the
    /// pipeline's p2p hop). Applied at every interior boundary whatever
    /// `pp` is, so stage placement stays a physical choice.
    fn boundary_qdq(&self, shard: u64, boundary: u64, site: u64, x: Vec<f32>, cols: usize) -> Vec<f32> {
        if self.wire != ReduceMode::Mxfp4 {
            return x;
        }
        let salt = self.site_salt(shard, boundary, site, 0);
        self.be.all_gather_mxfp4(&[&x], cols, &[salt])
    }
}

// ---- transformer ---------------------------------------------------------

/// Per-slice attention residue (everything downstream of the head-group
/// split, including the `wo` column-slice cache).
struct AttnSlice {
    lq: LinearCache,
    lk: LinearCache,
    lv: LinearCache,
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    probs: Vec<f32>,
    lo: LinearCache,
}

/// Per-slice SwiGLU residue for one `d_ff` range.
struct MlpSlice {
    lg: LinearCache,
    lu: LinearCache,
    gate: Vec<f32>,
    up: Vec<f32>,
    ld: LinearCache,
}

/// Forward residue of one block under tensor sharding: the shared
/// residual-stream tensors plus one slice struct per tensor shard.
struct TopoBlockCache {
    x_in: Vec<f32>,
    attn_inv: Vec<f32>,
    attn: Vec<AttnSlice>,
    x_mid: Vec<f32>,
    mlp_inv: Vec<f32>,
    mlp: Vec<MlpSlice>,
}

/// One microbatch's worth of topology-aware transformer compute. Cheap to
/// construct (all refs); built per `(shard)` so the salts key correctly.
struct TfShard<'a> {
    ctx: &'a TopoCtx<'a>,
    model: &'a TransformerLm,
    b_sh: usize,
    shard: u64,
}

impl TfShard<'_> {
    fn rows(&self) -> usize {
        self.b_sh * self.model.cfg.seq
    }

    /// Token embedding gather (stage 0 owns this).
    fn embed(&self, inputs: &[u32]) -> Vec<f32> {
        let d = self.model.cfg.d_model;
        let vocab = self.model.cfg.vocab;
        let mut x = vec![0.0f32; inputs.len() * d];
        for (r, &t) in inputs.iter().enumerate() {
            let src = (t as usize % vocab) * d;
            x[r * d..(r + 1) * d].copy_from_slice(&self.model.tok_emb[src..src + d]);
        }
        x
    }

    /// Forward one block with `ts`-way tensor sharding. The norm/residual
    /// path is computed once; the matmuls fan out over [`run_sharded`]
    /// with `tp` physical ranks.
    fn block_forward(&self, bi: usize, x_in: Vec<f32>) -> (Vec<f32>, TopoBlockCache) {
        let cfg = &self.model.cfg;
        let (d, h, hd, s) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.seq);
        let method = cfg.method;
        let be = self.ctx.be;
        let rows = self.rows();
        let ts = self.ctx.ts;
        let hpr = h / ts; // heads per slice
        let dpr = hpr * hd; // attention columns per slice
        let fpr = cfg.d_ff / ts; // d_ff rows per slice
        let scale = 1.0 / (hd as f32).sqrt();
        let block = &self.model.blocks[bi];

        let (a, attn_inv) = rmsnorm_rows(&x_in, &block.attn_norm, d);
        let attn_out_parts = run_sharded(ts, self.ctx.tp, |sl| {
            let (r0, r1) = (sl * dpr, (sl + 1) * dpr);
            let wq = row_slice(&block.wq.w, d, r0, r1);
            let wk = row_slice(&block.wk.w, d, r0, r1);
            let wv = row_slice(&block.wv.w, d, r0, r1);
            // every method's forward is deterministic — the stream is inert
            let mut rng = Rng::new(0);
            let (mut q, lq) = forward_with(&wq, dpr, d, &a, rows, method, be, &mut rng);
            let (mut k, lk) = forward_with(&wk, dpr, d, &a, rows, method, be, &mut rng);
            let (v, lv) = forward_with(&wv, dpr, d, &a, rows, method, be, &mut rng);
            for r in 0..rows {
                let pos = r % s;
                rope_row(&mut q[r * dpr..(r + 1) * dpr], hpr, hd, pos, false);
                rope_row(&mut k[r * dpr..(r + 1) * dpr], hpr, hd, pos, false);
            }
            let qh = split_heads(&q, self.b_sh, s, hpr, hd);
            let kh = split_heads(&k, self.b_sh, s, hpr, hd);
            let vh = split_heads(&v, self.b_sh, s, hpr, hd);
            let (ctxh, probs) =
                be.attention_causal(&qh, &kh, &vh, self.b_sh * hpr, s, s, hd, 0, scale);
            let ctx = merge_heads(&ctxh, self.b_sh, s, hpr, hd);
            let wo = col_slice(&block.wo.w, d, d, r0, r1);
            let (o_part, lo) = forward_with(&wo, d, dpr, &ctx, rows, method, be, &mut rng);
            (o_part, AttnSlice { lq, lk, lv, qh, kh, vh, probs, lo })
        });
        let (o_parts, attn): (Vec<_>, Vec<_>) = attn_out_parts.into_iter().unzip();
        let attn_out = self
            .ctx
            .wire_allreduce(self.shard, bi as u64, SITE_FWD_O, o_parts, rows, d);
        let mut x_mid = x_in.clone();
        add_assign(&mut x_mid, &attn_out);

        let (m, mlp_inv) = rmsnorm_rows(&x_mid, &block.mlp_norm, d);
        let mlp_out_parts = run_sharded(ts, self.ctx.tp, |sl| {
            let (r0, r1) = (sl * fpr, (sl + 1) * fpr);
            let wg = row_slice(&block.w_gate.w, d, r0, r1);
            let wu = row_slice(&block.w_up.w, d, r0, r1);
            let mut rng = Rng::new(0);
            let (gate, lg) = forward_with(&wg, fpr, d, &m, rows, method, be, &mut rng);
            let (up, lu) = forward_with(&wu, fpr, d, &m, rows, method, be, &mut rng);
            let hsw: Vec<f32> =
                gate.iter().zip(&up).map(|(&g0, &u0)| silu(g0) * u0).collect();
            let wd = col_slice(&block.w_down.w, d, cfg.d_ff, r0, r1);
            let (down_part, ld) = forward_with(&wd, d, fpr, &hsw, rows, method, be, &mut rng);
            (down_part, MlpSlice { lg, lu, gate, up, ld })
        });
        let (d_parts, mlp): (Vec<_>, Vec<_>) = mlp_out_parts.into_iter().unzip();
        let down =
            self.ctx
                .wire_allreduce(self.shard, bi as u64, SITE_FWD_DOWN, d_parts, rows, d);
        let mut x_out = x_mid.clone();
        add_assign(&mut x_out, &down);
        (x_out, TopoBlockCache { x_in, attn_inv, attn, x_mid, mlp_inv, mlp })
    }

    /// Backward one block. SR streams are keyed per `(shard, block,
    /// slice)` so slice evaluation order — and thread placement — cannot
    /// change the bits.
    fn block_backward(
        &self,
        bi: usize,
        mut dx: Vec<f32>,
        c: TopoBlockCache,
    ) -> (Vec<f32>, TfBlockGrads) {
        let cfg = &self.model.cfg;
        let (d, h, hd, s) = (cfg.d_model, cfg.n_heads, cfg.head_dim(), cfg.seq);
        let method = cfg.method;
        let be = self.ctx.be;
        let rows = self.rows();
        let ts = self.ctx.ts;
        let hpr = h / ts;
        let dpr = hpr * hd;
        let fpr = cfg.d_ff / ts;
        let scale = 1.0 / (hd as f32).sqrt();
        let block = &self.model.blocks[bi];

        // MLP branch: x_out = x_mid + down(silu(gate(m))·up(m))
        let mlp_parts = run_sharded(ts, self.ctx.tp, |sl| {
            let (r0, r1) = (sl * fpr, (sl + 1) * fpr);
            let slice = &c.mlp[sl];
            let mut rng =
                Rng::new(self.ctx.site_salt(self.shard, bi as u64, SITE_MLP_STREAM, sl as u64));
            let wd = col_slice(&block.w_down.w, d, cfg.d_ff, r0, r1);
            let (dh, dwd) = backward_with(&wd, d, fpr, &dx, &slice.ld, rows, method, be, &mut rng);
            let mut dgate = vec![0.0f32; rows * fpr];
            let mut dup = vec![0.0f32; rows * fpr];
            for i in 0..rows * fpr {
                let g0 = slice.gate[i];
                let sg = sigmoid(g0);
                dgate[i] = dh[i] * slice.up[i] * (sg * (1.0 + g0 * (1.0 - sg)));
                dup[i] = dh[i] * (g0 * sg);
            }
            let wg = row_slice(&block.w_gate.w, d, r0, r1);
            let (dm1, dwg) =
                backward_with(&wg, fpr, d, &dgate, &slice.lg, rows, method, be, &mut rng);
            let wu = row_slice(&block.w_up.w, d, r0, r1);
            let (dm2, dwu) =
                backward_with(&wu, fpr, d, &dup, &slice.lu, rows, method, be, &mut rng);
            let mut dm = dm1;
            add_assign(&mut dm, &dm2);
            (dm, dwg, dwu, dwd)
        });
        let mut w_gate = Vec::with_capacity(cfg.d_ff * d);
        let mut w_up = Vec::with_capacity(cfg.d_ff * d);
        let mut w_down = vec![0.0f32; d * cfg.d_ff];
        let mut dm_parts = Vec::with_capacity(ts);
        for (sl, (dm, dwg, dwu, dwd)) in mlp_parts.into_iter().enumerate() {
            dm_parts.push(dm);
            w_gate.extend_from_slice(&dwg);
            w_up.extend_from_slice(&dwu);
            col_scatter(&mut w_down, cfg.d_ff, sl * fpr, &dwd, fpr);
        }
        let dm = self
            .ctx
            .wire_allreduce(self.shard, bi as u64, SITE_BWD_DM, dm_parts, rows, d);
        let (dxm, mlp_norm) = rmsnorm_backward(&dm, &c.x_mid, &block.mlp_norm, &c.mlp_inv, d);
        add_assign(&mut dx, &dxm);

        // attention branch: x_mid = x_in + wo(attn(q,k,v))
        let attn_parts = run_sharded(ts, self.ctx.tp, |sl| {
            let (r0, r1) = (sl * dpr, (sl + 1) * dpr);
            let slice = &c.attn[sl];
            let mut rng =
                Rng::new(self.ctx.site_salt(self.shard, bi as u64, SITE_ATTN_STREAM, sl as u64));
            let wo = col_slice(&block.wo.w, d, d, r0, r1);
            let (dctx, dwo) =
                backward_with(&wo, d, dpr, &dx, &slice.lo, rows, method, be, &mut rng);
            let dctxh = split_heads(&dctx, self.b_sh, s, hpr, hd);
            let (dqh, dkh, dvh) = attention_backward(
                &slice.qh,
                &slice.kh,
                &slice.vh,
                &slice.probs,
                &dctxh,
                self.b_sh * hpr,
                s,
                s,
                hd,
                0,
                scale,
            );
            let mut dq = merge_heads(&dqh, self.b_sh, s, hpr, hd);
            let mut dk = merge_heads(&dkh, self.b_sh, s, hpr, hd);
            let dv = merge_heads(&dvh, self.b_sh, s, hpr, hd);
            for r in 0..rows {
                let pos = r % s;
                rope_row(&mut dq[r * dpr..(r + 1) * dpr], hpr, hd, pos, true);
                rope_row(&mut dk[r * dpr..(r + 1) * dpr], hpr, hd, pos, true);
            }
            let wq = row_slice(&block.wq.w, d, r0, r1);
            let (da1, dwq) = backward_with(&wq, dpr, d, &dq, &slice.lq, rows, method, be, &mut rng);
            let wk = row_slice(&block.wk.w, d, r0, r1);
            let (da2, dwk) = backward_with(&wk, dpr, d, &dk, &slice.lk, rows, method, be, &mut rng);
            let wv = row_slice(&block.wv.w, d, r0, r1);
            let (da3, dwv) = backward_with(&wv, dpr, d, &dv, &slice.lv, rows, method, be, &mut rng);
            let mut da = da1;
            add_assign(&mut da, &da2);
            add_assign(&mut da, &da3);
            (da, dwq, dwk, dwv, dwo)
        });
        let mut wq_g = Vec::with_capacity(d * d);
        let mut wk_g = Vec::with_capacity(d * d);
        let mut wv_g = Vec::with_capacity(d * d);
        let mut wo_g = vec![0.0f32; d * d];
        let mut da_parts = Vec::with_capacity(ts);
        for (sl, (da, dwq, dwk, dwv, dwo)) in attn_parts.into_iter().enumerate() {
            da_parts.push(da);
            wq_g.extend_from_slice(&dwq);
            wk_g.extend_from_slice(&dwk);
            wv_g.extend_from_slice(&dwv);
            col_scatter(&mut wo_g, d, sl * dpr, &dwo, dpr);
        }
        let da = self
            .ctx
            .wire_allreduce(self.shard, bi as u64, SITE_BWD_DA, da_parts, rows, d);
        let (dxa, attn_norm) = rmsnorm_backward(&da, &c.x_in, &block.attn_norm, &c.attn_inv, d);
        add_assign(&mut dx, &dxa);

        (
            dx,
            TfBlockGrads {
                attn_norm,
                wq: wq_g,
                wk: wk_g,
                wv: wv_g,
                wo: wo_g,
                mlp_norm,
                w_gate,
                w_up,
                w_down,
            },
        )
    }

    /// Forward blocks `[lo, hi)`, applying the boundary wire crossing
    /// before every interior block.
    fn stage_forward(
        &self,
        lo: usize,
        hi: usize,
        mut x: Vec<f32>,
    ) -> (Vec<f32>, Vec<TopoBlockCache>) {
        let d = self.model.cfg.d_model;
        let mut caches = Vec::with_capacity(hi - lo);
        for bi in lo..hi {
            if bi > 0 {
                x = self
                    .ctx
                    .boundary_qdq(self.shard, bi as u64, SITE_FWD_BOUNDARY, x, d);
            }
            let (x_out, c) = self.block_forward(bi, x);
            x = x_out;
            caches.push(c);
        }
        (x, caches)
    }

    /// Backward blocks `[lo, hi)` in reverse; returns the gradient flowing
    /// out of block `lo` and the per-block grads in block order.
    fn stage_backward(
        &self,
        lo: usize,
        hi: usize,
        mut dx: Vec<f32>,
        caches: Vec<TopoBlockCache>,
    ) -> (Vec<f32>, Vec<TfBlockGrads>) {
        let d = self.model.cfg.d_model;
        let mut grads = Vec::with_capacity(hi - lo);
        for (i, c) in caches.into_iter().enumerate().rev() {
            let bi = lo + i;
            let (dx_out, g) = self.block_backward(bi, dx, c);
            dx = dx_out;
            grads.push(g);
            if bi > 0 {
                dx = self
                    .ctx
                    .boundary_qdq(self.shard, bi as u64, SITE_BWD_BOUNDARY, dx, d);
            }
        }
        grads.reverse();
        (dx, grads)
    }

    /// Final norm + tied vocab head, forward and backward (the last
    /// stage owns this). Returns `(loss, dx into the top block, dW of the
    /// tied embedding from the head, final-norm grad)`.
    fn head_forward_backward(
        &self,
        x: &[f32],
        targets: &[u32],
    ) -> (f64, Vec<f32>, Vec<f32>, Vec<f32>) {
        let cfg = &self.model.cfg;
        let d = cfg.d_model;
        let rows = self.rows();
        let be = self.ctx.be;
        let (hn, final_inv) = rmsnorm_rows(x, &self.model.final_norm, d);
        let mut fwd_rng = Rng::new(0);
        let (logits, head) =
            forward_with(&self.model.tok_emb, cfg.vocab, d, &hn, rows, cfg.method, be, &mut fwd_rng);
        let (loss, dlogits) = softmax_xent(&logits, targets, cfg.vocab, true);
        let dlogits = dlogits.expect("grad requested");
        let l = self.model.blocks.len() as u64;
        let mut rng = Rng::new(self.ctx.site_salt(self.shard, l, SITE_HEAD_STREAM, 0));
        let (dhn, de) = backward_with(
            &self.model.tok_emb,
            cfg.vocab,
            d,
            &dlogits,
            &head,
            rows,
            cfg.method,
            be,
            &mut rng,
        );
        let (dx, fng) = rmsnorm_backward(&dhn, x, &self.model.final_norm, &final_inv, d);
        (loss, dx, de, fng)
    }

    /// Scatter the embedding-output gradient into the tied table, in the
    /// same row order the sequential path uses.
    fn scatter_embedding(&self, de: &mut [f32], inputs: &[u32], dx: &[f32]) {
        let d = self.model.cfg.d_model;
        let vocab = self.model.cfg.vocab;
        for (r, &t) in inputs.iter().enumerate() {
            let dst = (t as usize % vocab) * d;
            for j in 0..d {
                de[dst + j] += dx[r * d + j];
            }
        }
    }

    /// One full microbatch, sequential over all blocks (the `pp = 1`
    /// executor; also the reference the pipeline must bit-match).
    fn run(&self, toks_sh: &[u32]) -> (f64, TfGrads) {
        let cfg = &self.model.cfg;
        let l = self.model.blocks.len();
        let (inputs, targets) = split_windows(toks_sh, self.b_sh, cfg.seq);
        let x = self.embed(&inputs);
        let (x, caches) = self.stage_forward(0, l, x);
        let (loss, dx, mut de, final_norm) = self.head_forward_backward(&x, &targets);
        let (dx, blocks) = self.stage_backward(0, l, dx, caches);
        self.scatter_embedding(&mut de, &inputs, &dx);
        (loss, TfGrads { tok_emb: de, blocks, final_norm })
    }
}

// ---- the 1F1B pipeline executor ------------------------------------------

/// What one stage hands back for one microbatch.
struct StageK {
    blocks: Vec<TfBlockGrads>,
    /// stage 0 only: gradient w.r.t. the embedding output
    dx_emb: Option<Vec<f32>>,
    /// last stage only: (loss, tied-head dW, final-norm grad)
    head: Option<(f64, Vec<f32>, Vec<f32>)>,
}

#[derive(Clone, Copy, PartialEq)]
enum Op {
    Fwd,
    Bwd,
}

/// The deterministic 1F1B schedule for one stage: `warm` forwards, then
/// strict backward/forward alternation, then the backward drain. The last
/// stage couples each forward to its backward directly.
fn stage_ops(si: usize, p: usize, f: usize) -> Vec<Op> {
    let mut ops = Vec::with_capacity(2 * f);
    if si == p - 1 {
        for _ in 0..f {
            ops.push(Op::Fwd);
            ops.push(Op::Bwd);
        }
    } else {
        let warm = (p - 1 - si).min(f);
        for _ in 0..warm {
            ops.push(Op::Fwd);
        }
        for _ in warm..f {
            ops.push(Op::Bwd);
            ops.push(Op::Fwd);
        }
        for _ in 0..warm {
            ops.push(Op::Bwd);
        }
    }
    ops
}

/// Run every gradient shard as a pipeline microbatch across `pp` stage
/// threads (1F1B), returning per-shard `(loss, grads)` in shard order —
/// bit-identical to the sequential executor because all state is keyed by
/// `(shard, block, site)`, never by stage or schedule position.
fn run_pipeline_transformer(
    ctx: &TopoCtx<'_>,
    model: &TransformerLm,
    toks: &[u32],
    b_sh: usize,
    shards: usize,
    pp: usize,
) -> Vec<(f64, TfGrads)> {
    let cfg = &model.cfg;
    let l = model.blocks.len();
    let win = cfg.seq + 1;
    let ranges = stage_ranges(l, pp);
    let p = ranges.len();
    let f = shards;

    type Msg = (usize, Vec<f32>);
    let mut fwd_txs: Vec<Option<Sender<Msg>>> = (0..p).map(|_| None).collect();
    let mut fwd_rxs: Vec<Option<Receiver<Msg>>> = (0..p).map(|_| None).collect();
    let mut bwd_txs: Vec<Option<Sender<Msg>>> = (0..p).map(|_| None).collect();
    let mut bwd_rxs: Vec<Option<Receiver<Msg>>> = (0..p).map(|_| None).collect();
    for i in 0..p - 1 {
        let (t, r) = channel();
        fwd_txs[i] = Some(t);
        fwd_rxs[i + 1] = Some(r);
        let (t, r) = channel();
        bwd_txs[i + 1] = Some(t);
        bwd_rxs[i] = Some(r);
    }

    let mut stage_outs: Vec<Vec<StageK>> = Vec::with_capacity(p);
    std::thread::scope(|sc| {
        let mut handles = Vec::with_capacity(p);
        for si in 0..p {
            let (lo, hi) = ranges[si];
            let fwd_rx = fwd_rxs[si].take();
            let fwd_tx = fwd_txs[si].take();
            let bwd_rx = bwd_rxs[si].take();
            let bwd_tx = bwd_txs[si].take();
            handles.push(sc.spawn(move || {
                let first = si == 0;
                let last = si == p - 1;
                let mut caches: VecDeque<(usize, Vec<TopoBlockCache>, Option<Vec<f32>>)> =
                    VecDeque::new();
                let mut outs: Vec<StageK> = (0..f)
                    .map(|_| StageK { blocks: Vec::new(), dx_emb: None, head: None })
                    .collect();
                let (mut next_f, mut next_b) = (0usize, 0usize);
                for op in stage_ops(si, p, f) {
                    match op {
                        Op::Fwd => {
                            let k = next_f;
                            next_f += 1;
                            let run = TfShard { ctx, model, b_sh, shard: k as u64 };
                            let x = if first {
                                let lo_t = k * b_sh * win;
                                let (inputs, _) =
                                    split_windows(&toks[lo_t..lo_t + b_sh * win], b_sh, cfg.seq);
                                run.embed(&inputs)
                            } else {
                                let (kk, x) =
                                    fwd_rx.as_ref().unwrap().recv().expect("pipeline fwd recv");
                                assert_eq!(kk, k, "microbatches must arrive in order");
                                x
                            };
                            let (x, cs) = run.stage_forward(lo, hi, x);
                            if last {
                                caches.push_back((k, cs, Some(x)));
                            } else {
                                caches.push_back((k, cs, None));
                                fwd_tx.as_ref().unwrap().send((k, x)).expect("pipeline fwd send");
                            }
                        }
                        Op::Bwd => {
                            let k = next_b;
                            next_b += 1;
                            let (kk, cs, x_last) = caches.pop_front().expect("cache underflow");
                            assert_eq!(kk, k, "1F1B consumes microbatches in order");
                            let run = TfShard { ctx, model, b_sh, shard: k as u64 };
                            let dx = if last {
                                let lo_t = k * b_sh * win;
                                let (_, targets) =
                                    split_windows(&toks[lo_t..lo_t + b_sh * win], b_sh, cfg.seq);
                                let (loss, dx, de, fng) =
                                    run.head_forward_backward(&x_last.unwrap(), &targets);
                                outs[k].head = Some((loss, de, fng));
                                dx
                            } else {
                                let (kk2, dx) =
                                    bwd_rx.as_ref().unwrap().recv().expect("pipeline bwd recv");
                                assert_eq!(kk2, k, "gradients must arrive in order");
                                dx
                            };
                            let (dx, blocks) = run.stage_backward(lo, hi, dx, cs);
                            outs[k].blocks = blocks;
                            if first {
                                outs[k].dx_emb = Some(dx);
                            } else {
                                bwd_tx.as_ref().unwrap().send((k, dx)).expect("pipeline bwd send");
                            }
                        }
                    }
                }
                outs
            }));
        }
        for h in handles {
            stage_outs.push(h.join().expect("pipeline stage panicked"));
        }
    });

    // stitch each microbatch's stage outputs back into one TfGrads
    let mut results = Vec::with_capacity(f);
    for k in 0..f {
        let (loss, mut de, final_norm) =
            stage_outs[p - 1][k].head.take().expect("last stage output");
        let dx = stage_outs[0][k].dx_emb.take().expect("stage 0 output");
        let lo_t = k * b_sh * win;
        let (inputs, _) = split_windows(&toks[lo_t..lo_t + b_sh * win], b_sh, cfg.seq);
        let run = TfShard { ctx, model, b_sh, shard: k as u64 };
        run.scatter_embedding(&mut de, &inputs, &dx);
        let mut blocks = Vec::with_capacity(l);
        for so in stage_outs.iter_mut() {
            blocks.append(&mut so[k].blocks);
        }
        results.push((loss, TfGrads { tok_emb: de, blocks, final_norm }));
    }
    results
}

// ---- entry points --------------------------------------------------------

/// One topology-aware transformer step: TP-sharded block matmuls, the
/// boundary wire crossings, the (optional) 1F1B pipeline, then the usual
/// DP gradient reduction. Loss bits depend only on
/// `(seed, step, shards, ts, wire)`; `workers`, `tp` and `pp` are pure
/// placement. Returns `(loss, grads, per-collective comms bytes)`.
#[allow(clippy::too_many_arguments)]
pub fn dist_loss_and_grads_topo_transformer(
    model: &TransformerLm,
    toks: &[u32],
    b: usize,
    d: &DistOptions,
    topo: &Topology,
    be: &dyn Backend,
    seed: u64,
    step: usize,
) -> (f64, TfGrads, CommsBytes) {
    validate_topo_transformer(&model.cfg, topo).expect("topology validated by caller");
    let shards = d.shards.max(1);
    assert_eq!(b % shards, 0, "batch must tile into shards (DistOptions::validate)");
    let win = model.cfg.seq + 1;
    assert_eq!(toks.len(), b * win);
    let b_sh = b / shards;
    let l = model.blocks.len();
    let ctx = TopoCtx {
        be,
        ts: topo.ts.max(1),
        tp: topo.effective_tp(),
        wire: topo.wire,
        seed,
        step: step as u64,
    };
    let pp_eff = topo.pp.clamp(1, l);

    let results: Vec<(f64, TfGrads)> = if pp_eff > 1 {
        run_pipeline_transformer(&ctx, model, toks, b_sh, shards, pp_eff)
    } else {
        run_sharded(shards, d.effective_workers(), |sh| {
            let lo = sh * b_sh * win;
            TfShard { ctx: &ctx, model, b_sh, shard: sh as u64 }
                .run(&toks[lo..lo + b_sh * win])
        })
    };

    let (loss, grads, dp_payload) = reduce_tf_shards(model, &results, d, be, seed, step);
    let comms = topo_comms_transformer(&model.cfg, b, d, topo, dp_payload);
    (loss, grads, comms)
}

/// DP-reduce per-shard transformer grads — same tensor ids and fold order
/// as `dist_loss_and_grads_transformer`, so the DP wire streams are shared
/// between the plain and topology-aware paths.
fn reduce_tf_shards(
    model: &TransformerLm,
    results: &[(f64, TfGrads)],
    d: &DistOptions,
    be: &dyn Backend,
    seed: u64,
    step: usize,
) -> (f64, TfGrads, f64) {
    let shards = results.len();
    let loss = results.iter().map(|(l, _)| *l).sum::<f64>() / shards as f64;
    let weight = 1.0 / shards as f32;
    let cfg = &model.cfg;
    let mut reducer = GradReducer::new(be, d.reduce, seed, step);

    let emb_parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.tok_emb.as_slice()).collect();
    let tok_emb = reducer.reduce(&emb_parts, weight, cfg.vocab, cfg.d_model, 0);
    let mut blocks = Vec::with_capacity(model.blocks.len());
    for bi in 0..model.blocks.len() {
        let base = 1 + bi as u64 * 9;
        let pick = |sel: fn(&TfBlockGrads) -> &Vec<f32>| -> Vec<&[f32]> {
            results.iter().map(|(_, g)| sel(&g.blocks[bi]).as_slice()).collect()
        };
        blocks.push(TfBlockGrads {
            attn_norm: reducer.reduce(&pick(|g| &g.attn_norm), weight, 1, cfg.d_model, base),
            wq: reducer.reduce(&pick(|g| &g.wq), weight, cfg.d_model, cfg.d_model, base + 1),
            wk: reducer.reduce(&pick(|g| &g.wk), weight, cfg.d_model, cfg.d_model, base + 2),
            wv: reducer.reduce(&pick(|g| &g.wv), weight, cfg.d_model, cfg.d_model, base + 3),
            wo: reducer.reduce(&pick(|g| &g.wo), weight, cfg.d_model, cfg.d_model, base + 4),
            mlp_norm: reducer.reduce(&pick(|g| &g.mlp_norm), weight, 1, cfg.d_model, base + 5),
            w_gate: reducer.reduce(&pick(|g| &g.w_gate), weight, cfg.d_ff, cfg.d_model, base + 6),
            w_up: reducer.reduce(&pick(|g| &g.w_up), weight, cfg.d_ff, cfg.d_model, base + 7),
            w_down: reducer.reduce(&pick(|g| &g.w_down), weight, cfg.d_model, cfg.d_ff, base + 8),
        });
    }
    let fin_parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.final_norm.as_slice()).collect();
    let final_norm =
        reducer.reduce(&fin_parts, weight, 1, cfg.d_model, 1 + model.blocks.len() as u64 * 9);
    (loss, TfGrads { tok_emb, blocks, final_norm }, reducer.payload_bytes)
}

/// Analytic per-collective volume of one topology-aware transformer step.
/// Per block and microbatch there are four TP all-reduces of a
/// `[rows, d_model]` tensor, each a reduce-scatter plus an all-gather of
/// `(tp−1)·payload` bytes at wire precision; each physical stage boundary
/// moves one activation forward and one gradient backward per microbatch;
/// the DP ring is the same `2·(W−1)·payload` as the plain dist path.
pub fn topo_comms_transformer(
    cfg: &TransformerConfig,
    b: usize,
    d: &DistOptions,
    topo: &Topology,
    dp_payload_bytes: f64,
) -> CommsBytes {
    let shards = d.shards.max(1);
    let rows = (b / shards.max(1)).max(1) * cfg.seq;
    let tp = topo.effective_tp();
    let pp = topo.pp.clamp(1, cfg.n_layers.max(1));
    let act = topo.wire.payload_bytes(rows * cfg.d_model);
    let per_site = (tp - 1) as f64 * act;
    let sites = (shards * cfg.n_layers * 4) as f64;
    CommsBytes {
        allreduce: ring_allreduce_bytes(d.effective_workers(), dp_payload_bytes),
        reduce_scatter: sites * per_site,
        all_gather: sites * per_site,
        p2p: (shards * 2 * (pp - 1)) as f64 * act,
    }
}

// ---- MLP architecture ----------------------------------------------------

/// One microbatch of the TP-sharded MLP stack: hidden layers
/// column-parallel over `d_hidden` row ranges (slice-local ReLU, then an
/// all-gather reassembles the activation), vocab projection replicated.
struct MlpShard<'a> {
    ctx: &'a TopoCtx<'a>,
    model: &'a MlpLm,
    shard: u64,
}

impl MlpShard<'_> {
    /// Reassemble column-parallel slice outputs `[rows, w]` each into the
    /// full `[rows, ts·w]` activation, QDQing every slice through the wire
    /// on the way (the forward all-gather).
    fn wire_gather_cols(&self, parts: Vec<Vec<f32>>, rows: usize, w: usize, li: usize) -> Vec<f32> {
        let ts = self.ctx.ts;
        if ts == 1 {
            return parts.into_iter().next().unwrap();
        }
        let parts: Vec<Vec<f32>> = if self.ctx.wire == ReduceMode::Mxfp4 {
            let base = self.ctx.site_salt(self.shard, li as u64, SITE_MLP_FWD_AG, 0);
            let salts: Vec<u64> = (0..ts).map(|p| sub_salt(base, p as u64)).collect();
            let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
            let cat = self.ctx.be.all_gather_mxfp4(&refs, w, &salts);
            (0..ts).map(|p| cat[p * rows * w..(p + 1) * rows * w].to_vec()).collect()
        } else {
            parts
        };
        let d = ts * w;
        let mut out = vec![0.0f32; rows * d];
        for (p, part) in parts.iter().enumerate() {
            for r in 0..rows {
                out[r * d + p * w..r * d + (p + 1) * w]
                    .copy_from_slice(&part[r * w..(r + 1) * w]);
            }
        }
        out
    }

    fn run(&self, ctx_pairs: &[(u32, u32)], targets: &[u32]) -> (f64, Grads) {
        let b = ctx_pairs.len();
        let cfg = &self.model.cfg;
        let method: TrainMethod = cfg.method;
        let be = self.ctx.be;
        let ts = self.ctx.ts;
        let last = self.model.layers.len() - 1;
        let fpr = cfg.d_hidden / ts;

        // forward: sliced hidden stack, slice-local ReLU, wire all-gather
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(last + 1);
        let mut slice_caches: Vec<Vec<LinearCache>> = Vec::with_capacity(last);
        let mut x = self.model.features(ctx_pairs);
        for li in 0..last {
            let layer = &self.model.layers[li];
            let d_in = layer.d_in;
            let parts = run_sharded(ts, self.ctx.tp, |sl| {
                let ws = row_slice(&layer.w, d_in, sl * fpr, (sl + 1) * fpr);
                let mut rng = Rng::new(0); // forward is deterministic
                let (mut y, c) = forward_with(&ws, fpr, d_in, &x, b, method, be, &mut rng);
                relu(&mut y);
                (y, c)
            });
            let (ys, cs): (Vec<_>, Vec<_>) = parts.into_iter().unzip();
            slice_caches.push(cs);
            acts.push(x);
            x = self.wire_gather_cols(ys, b, fpr, li);
        }
        acts.push(x.clone());

        // replicated vocab projection + loss on the shared path
        let out_layer = &self.model.layers[last];
        let mut fwd_rng = Rng::new(0);
        let (logits, out_cache) = out_layer.forward(&x, b, method, be, &mut fwd_rng);
        let (loss, dlogits) = softmax_xent(&logits, targets, cfg.vocab, true);
        let mut dcur = dlogits.expect("grad requested");

        let mut grads = Grads {
            tok_emb: vec![0.0f32; self.model.tok_emb.len()],
            layers: vec![Vec::new(); self.model.layers.len()],
        };
        let mut orng =
            Rng::new(self.ctx.site_salt(self.shard, last as u64, SITE_MLP_OUT_STREAM, 0));
        let (dx, dw) = out_layer.backward(&dcur, &out_cache, b, method, be, &mut orng);
        grads.layers[last] = dw;
        dcur = dx
            .iter()
            .zip(&out_cache.x)
            .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
            .collect();

        // backward through the sliced stack: per-slice dy column ranges,
        // partial dx all-reduced through the wire
        for li in (0..last).rev() {
            let layer = &self.model.layers[li];
            let d_in = layer.d_in;
            let dy = dcur;
            let cs = &slice_caches[li];
            let out = run_sharded(ts, self.ctx.tp, |sl| {
                let ws = row_slice(&layer.w, d_in, sl * fpr, (sl + 1) * fpr);
                let dy_s = col_slice(&dy, b, cfg.d_hidden, sl * fpr, (sl + 1) * fpr);
                let mut rng = Rng::new(self.ctx.site_salt(
                    self.shard,
                    li as u64,
                    SITE_MLP_LAYER_STREAM,
                    sl as u64,
                ));
                backward_with(&ws, fpr, d_in, &dy_s, &cs[sl], b, method, be, &mut rng)
            });
            let (dxs, dws): (Vec<_>, Vec<_>) = out.into_iter().unzip();
            let dx = self
                .ctx
                .wire_allreduce(self.shard, li as u64, SITE_MLP_BWD_AR, dxs, b, d_in);
            let mut dw = Vec::with_capacity(layer.w.len());
            for w in dws {
                dw.extend_from_slice(&w);
            }
            grads.layers[li] = dw;
            if li > 0 {
                dcur = dx
                    .iter()
                    .zip(&acts[li])
                    .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                    .collect();
            } else {
                let d = cfg.d_emb;
                let v = cfg.vocab;
                for (s, &(a, p)) in ctx_pairs.iter().enumerate() {
                    let row = &dx[s * 2 * d..(s + 1) * 2 * d];
                    let ea = (a as usize % v) * d;
                    let ep = (p as usize % v) * d;
                    for i in 0..d {
                        grads.tok_emb[ea + i] += row[i];
                        grads.tok_emb[ep + i] += row[d + i];
                    }
                }
            }
        }
        (loss, grads)
    }
}

/// One topology-aware MLP step (TP only; `pp` must be 1 — validated).
/// The MLP twin of [`dist_loss_and_grads_topo_transformer`].
#[allow(clippy::too_many_arguments)]
pub fn dist_loss_and_grads_topo_mlp(
    model: &MlpLm,
    ctx_pairs: &[(u32, u32)],
    tgt: &[u32],
    d: &DistOptions,
    topo: &Topology,
    be: &dyn Backend,
    seed: u64,
    step: usize,
) -> (f64, Grads, CommsBytes) {
    validate_topo_mlp(&model.cfg, topo).expect("topology validated by caller");
    let b = ctx_pairs.len();
    let shards = d.shards.max(1);
    assert_eq!(b % shards, 0, "batch must tile into shards (DistOptions::validate)");
    assert_eq!(tgt.len(), b);
    let per = b / shards;
    let ctx = TopoCtx {
        be,
        ts: topo.ts.max(1),
        tp: topo.effective_tp(),
        wire: topo.wire,
        seed,
        step: step as u64,
    };

    let results = run_sharded(shards, d.effective_workers(), |sh| {
        let lo = sh * per;
        MlpShard { ctx: &ctx, model, shard: sh as u64 }
            .run(&ctx_pairs[lo..lo + per], &tgt[lo..lo + per])
    });

    let loss = results.iter().map(|(l, _)| *l).sum::<f64>() / shards as f64;
    let weight = 1.0 / shards as f32;
    let mut reducer = GradReducer::new(be, d.reduce, seed, step);
    let emb_parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.tok_emb.as_slice()).collect();
    let tok_emb = reducer.reduce(&emb_parts, weight, model.cfg.vocab, model.cfg.d_emb, 0);
    let mut layers = Vec::with_capacity(model.layers.len());
    for (li, layer) in model.layers.iter().enumerate() {
        let parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.layers[li].as_slice()).collect();
        layers.push(reducer.reduce(&parts, weight, layer.d_out, layer.d_in, (li + 1) as u64));
    }
    let comms = topo_comms_mlp(&model.cfg, b, d, topo, reducer.payload_bytes);
    (loss, Grads { tok_emb, layers }, comms)
}

/// Analytic per-collective volume of one topology-aware MLP step: per
/// sliced layer and microbatch, the forward all-gathers the sliced
/// activation and the backward all-reduces (reduce-scatter + all-gather)
/// the partial input gradient. No pipeline axis.
pub fn topo_comms_mlp(
    cfg: &ModelConfig,
    b: usize,
    d: &DistOptions,
    topo: &Topology,
    dp_payload_bytes: f64,
) -> CommsBytes {
    let shards = d.shards.max(1);
    let rows = b / shards.max(1);
    let tp = topo.effective_tp();
    let dims = cfg.layer_dims();
    let (mut rs, mut ag) = (0.0f64, 0.0f64);
    for &(d_out, d_in) in &dims[..dims.len() - 1] {
        ag += (tp - 1) as f64
            * (topo.wire.payload_bytes(rows * d_out) + topo.wire.payload_bytes(rows * d_in));
        rs += (tp - 1) as f64 * topo.wire.payload_bytes(rows * d_in);
    }
    CommsBytes {
        allreduce: ring_allreduce_bytes(d.effective_workers(), dp_payload_bytes),
        reduce_scatter: shards as f64 * rs,
        all_gather: shards as f64 * ag,
        p2p: 0.0,
    }
}

// ---- tests ---------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    fn tf_cfg() -> TransformerConfig {
        TransformerConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq: 4,
            method: TrainMethod::Quartet,
        }
    }

    fn mlp_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_emb: 16,
            d_hidden: 64,
            n_hidden: 1,
            method: TrainMethod::Quartet,
        }
    }

    #[test]
    fn slice_helpers_roundtrip() {
        // [3, 4] matrix; carve columns [1, 3) out and scatter them back
        let w: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let rs = row_slice(&w, 4, 1, 3);
        assert_eq!(rs, &w[4..12]);
        let cs = col_slice(&w, 3, 4, 1, 3);
        assert_eq!(cs, vec![1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
        let mut back = vec![0.0f32; 12];
        col_scatter(&mut back, 4, 1, &cs, 2);
        for r in 0..3 {
            assert_eq!(back[r * 4 + 1], w[r * 4 + 1]);
            assert_eq!(back[r * 4 + 2], w[r * 4 + 2]);
            assert_eq!(back[r * 4], 0.0);
            assert_eq!(back[r * 4 + 3], 0.0);
        }
    }

    #[test]
    fn stage_ranges_are_balanced_and_contiguous() {
        assert_eq!(stage_ranges(2, 1), vec![(0, 2)]);
        assert_eq!(stage_ranges(2, 2), vec![(0, 1), (1, 2)]);
        assert_eq!(stage_ranges(5, 2), vec![(0, 3), (3, 5)]);
        assert_eq!(stage_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]); // pp clamps to L
        let r = stage_ranges(7, 3);
        assert_eq!(r, vec![(0, 3), (3, 5), (5, 7)]);
    }

    #[test]
    fn stage_ops_conserve_microbatches() {
        for p in 1..=4 {
            for f in 1..=5 {
                for si in 0..p {
                    let ops = stage_ops(si, p, f);
                    assert_eq!(ops.iter().filter(|&&o| o == Op::Fwd).count(), f);
                    assert_eq!(ops.iter().filter(|&&o| o == Op::Bwd).count(), f);
                    // a backward can never outpace its own forward
                    let (mut fs, mut bs) = (0, 0);
                    for op in ops {
                        match op {
                            Op::Fwd => fs += 1,
                            Op::Bwd => {
                                bs += 1;
                                assert!(bs <= fs);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let cfg = tf_cfg();
        let ok = Topology { ts: 2, tp: 2, pp: 2, wire: ReduceMode::Mxfp4 };
        validate_topo_transformer(&cfg, &ok).unwrap();
        // ts must divide heads
        let t = Topology { ts: 3, ..ok };
        assert!(validate_topo_transformer(&cfg, &t).is_err());
        // slices must stay MX-aligned: d_model/4 = 16 < GROUP
        let wide = TransformerConfig { n_heads: 4, ..cfg.clone() };
        let t = Topology { ts: 4, ..ok };
        assert!(validate_topo_transformer(&wide, &t).is_err());
        // pp can't exceed the block count
        let t = Topology { pp: 3, ..ok };
        assert!(validate_topo_transformer(&cfg, &t).is_err());
        // MLP: no pipeline axis, and d_hidden slices must stay aligned
        let m = mlp_cfg();
        validate_topo_mlp(&m, &Topology { ts: 2, tp: 1, pp: 1, wire: ReduceMode::Mxfp4 }).unwrap();
        assert!(validate_topo_mlp(&m, &Topology { pp: 2, ..ok }).is_err());
        assert!(validate_topo_mlp(&m, &Topology { ts: 4, pp: 1, ..ok }).is_err());
    }

    #[test]
    fn comms_formulas_match_hand_computation() {
        let cfg = tf_cfg();
        let d = DistOptions { workers: 2, shards: 4, reduce: ReduceMode::F32 };
        // trivial topology: everything but the DP ring is zero
        let t1 = Topology::default();
        let c1 = topo_comms_transformer(&cfg, 8, &d, &t1, 1000.0);
        assert_eq!(c1.reduce_scatter, 0.0);
        assert_eq!(c1.all_gather, 0.0);
        assert_eq!(c1.p2p, 0.0);
        assert_eq!(c1.allreduce, ring_allreduce_bytes(2, 1000.0));
        // ts=tp=2, pp=2, mxfp4 wire: rows = (8/4)*4 = 8, act = 8*64 values
        let t2 = Topology { ts: 2, tp: 2, pp: 2, wire: ReduceMode::Mxfp4 };
        let c2 = topo_comms_transformer(&cfg, 8, &d, &t2, 1000.0);
        let act = ReduceMode::Mxfp4.payload_bytes(8 * 64);
        // 4 shards × 2 blocks × 4 sites × (tp−1)·act
        assert_eq!(c2.reduce_scatter, 32.0 * act);
        assert_eq!(c2.all_gather, 32.0 * act);
        // 4 shards × 2 directions × (pp−1) boundaries
        assert_eq!(c2.p2p, 8.0 * act);
        assert!((c2.total() - (c2.allreduce + 64.0 * act + 8.0 * act)).abs() < 1e-9);
        // tp clamps to ts: tp=4 at ts=2 moves the same bytes as tp=2
        let t3 = Topology { tp: 4, ..t2 };
        assert_eq!(topo_comms_transformer(&cfg, 8, &d, &t3, 1000.0), c2);

        // MLP: layers [(64, 32), (64, 64)] sliced, vocab layer free
        let m = mlp_cfg();
        let tm = Topology { ts: 2, tp: 2, pp: 1, wire: ReduceMode::Mxfp4 };
        let cm = topo_comms_mlp(&m, 8, &d, &tm, 500.0);
        let rows = 2; // 8 / 4 shards
        let pay = |v: usize| ReduceMode::Mxfp4.payload_bytes(v);
        let want_ag = 4.0 * ((pay(rows * 64) + pay(rows * 32)) + (pay(rows * 64) + pay(rows * 64)));
        let want_rs = 4.0 * (pay(rows * 32) + pay(rows * 64));
        assert_eq!(cm.all_gather, want_ag);
        assert_eq!(cm.reduce_scatter, want_rs);
        assert_eq!(cm.p2p, 0.0);
    }

    fn tf_fixture() -> (TransformerLm, Vec<u32>) {
        let model = TransformerLm::init(tf_cfg(), 21).unwrap();
        let mut rng = Rng::new(77);
        let toks: Vec<u32> =
            (0..8 * (tf_cfg().seq + 1)).map(|_| rng.below(tf_cfg().vocab) as u32).collect();
        (model, toks)
    }

    #[test]
    fn transformer_loss_is_invariant_under_physical_axes() {
        let (model, toks) = tf_fixture();
        let be = ScalarBackend;
        let d = |workers: usize| DistOptions { workers, shards: 4, reduce: ReduceMode::Mxfp4 };
        // fixed logical axes (shards=4, ts=2, mxfp4 wire); vary placement
        let topo = |tp: usize, pp: usize| Topology { ts: 2, tp, pp, wire: ReduceMode::Mxfp4 };
        let (l0, g0, c0) =
            dist_loss_and_grads_topo_transformer(&model, &toks, 8, &d(1), &topo(1, 1), &be, 9, 0);
        for (w, tp, pp) in [(2, 1, 1), (1, 2, 1), (1, 1, 2), (2, 2, 2)] {
            let (l, g, c) = dist_loss_and_grads_topo_transformer(
                &model, &toks, 8, &d(w), &topo(tp, pp), &be, 9, 0,
            );
            assert_eq!(l.to_bits(), l0.to_bits(), "loss must not depend on placement");
            assert_eq!(g.tok_emb, g0.tok_emb);
            assert_eq!(g.final_norm, g0.final_norm);
            for (bg, bg0) in g.blocks.iter().zip(&g0.blocks) {
                assert_eq!(bg.wq, bg0.wq);
                assert_eq!(bg.wo, bg0.wo);
                assert_eq!(bg.w_gate, bg0.w_gate);
                assert_eq!(bg.w_down, bg0.w_down);
                assert_eq!(bg.attn_norm, bg0.attn_norm);
            }
            // placement does change the physical accounting
            assert_eq!(c.p2p == 0.0, pp == 1);
            assert_eq!(c.reduce_scatter == 0.0, tp == 1);
            assert_eq!(c.allreduce == 0.0, w == 1);
            let _ = c0;
        }
    }

    #[test]
    fn transformer_ts_and_wire_are_logical_axes() {
        // changing ts or the wire format is *supposed* to change the bits
        let (model, toks) = tf_fixture();
        let be = ScalarBackend;
        let d = DistOptions { workers: 1, shards: 4, reduce: ReduceMode::F32 };
        let base = Topology { ts: 2, tp: 1, pp: 1, wire: ReduceMode::Mxfp4 };
        let (l0, _, _) =
            dist_loss_and_grads_topo_transformer(&model, &toks, 8, &d, &base, &be, 9, 0);
        let (l1, _, _) = dist_loss_and_grads_topo_transformer(
            &model,
            &toks,
            8,
            &d,
            &Topology { ts: 1, ..base },
            &be,
            9,
            0,
        );
        let (l2, _, _) = dist_loss_and_grads_topo_transformer(
            &model,
            &toks,
            8,
            &d,
            &Topology { wire: ReduceMode::F32, ..base },
            &be,
            9,
            0,
        );
        assert_ne!(l0.to_bits(), l1.to_bits(), "ts is logical");
        assert_ne!(l0.to_bits(), l2.to_bits(), "wire is logical");
    }

    #[test]
    fn mlp_loss_is_invariant_under_physical_axes() {
        let cfg = mlp_cfg();
        let model = MlpLm::init(cfg.clone(), 13).unwrap();
        let mut rng = Rng::new(31);
        let ctx_pairs: Vec<(u32, u32)> = (0..8)
            .map(|_| (rng.below(cfg.vocab) as u32, rng.below(cfg.vocab) as u32))
            .collect();
        let tgt: Vec<u32> = (0..8).map(|_| rng.below(cfg.vocab) as u32).collect();
        let be = ScalarBackend;
        let d = |workers: usize| DistOptions { workers, shards: 4, reduce: ReduceMode::Mxfp4 };
        let topo = |tp: usize| Topology { ts: 2, tp, pp: 1, wire: ReduceMode::Mxfp4 };
        let (l0, g0, _) =
            dist_loss_and_grads_topo_mlp(&model, &ctx_pairs, &tgt, &d(1), &topo(1), &be, 5, 3);
        for (w, tp) in [(2, 1), (1, 2), (4, 2)] {
            let (l, g, c) =
                dist_loss_and_grads_topo_mlp(&model, &ctx_pairs, &tgt, &d(w), &topo(tp), &be, 5, 3);
            assert_eq!(l.to_bits(), l0.to_bits());
            assert_eq!(g.tok_emb, g0.tok_emb);
            for (lw, lw0) in g.layers.iter().zip(&g0.layers) {
                assert_eq!(lw, lw0);
            }
            assert_eq!(c.reduce_scatter == 0.0, tp == 1);
            assert_eq!(c.p2p, 0.0);
        }
    }
}
