//! Adam with bias correction — the optimizer of every run in the paper's
//! testbed (β₁ 0.9, β₂ 0.999, ε 1e-8; weight decay is off, matching the
//! small-scale PJRT artifacts).

/// Adam over a fixed set of parameter tensors ("slots"); slot order is
/// the caller's contract (slot 0 = embeddings, then one per layer).
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(sizes: &[usize], lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
        }
    }

    /// Advance the shared step counter; call once per optimizer step,
    /// before the slot updates.
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Apply one Adam update to a slot's parameters in place.
    pub fn update(&mut self, slot: usize, w: &mut [f32], g: &[f32]) {
        assert!(self.t > 0, "call begin_step first");
        let (m, v) = (&mut self.m[slot], &mut self.v[slot]);
        assert_eq!(w.len(), m.len(), "slot {slot} size mismatch");
        assert_eq!(w.len(), g.len(), "slot {slot} grad mismatch");
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..w.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            w[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // L = ½‖w − target‖², gradient w − target
        let target = [3.0f32, -1.5, 0.25, 8.0];
        let mut w = [0.0f32; 4];
        let mut adam = Adam::new(&[4], 0.05);
        for _ in 0..2000 {
            let g: Vec<f32> = w.iter().zip(&target).map(|(a, b)| a - b).collect();
            adam.begin_step();
            adam.update(0, &mut w, &g);
        }
        for (a, b) in w.iter().zip(&target) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // bias correction makes the first update ≈ lr · sign(g)
        let mut w = [0.0f32; 2];
        let mut adam = Adam::new(&[2], 0.1);
        adam.begin_step();
        adam.update(0, &mut w, &[0.5, -2.0]);
        assert!((w[0] + 0.1).abs() < 1e-3, "{}", w[0]);
        assert!((w[1] - 0.1).abs() < 1e-3, "{}", w[1]);
    }

    #[test]
    #[should_panic(expected = "begin_step")]
    fn update_without_step_panics() {
        let mut adam = Adam::new(&[1], 0.1);
        adam.update(0, &mut [0.0], &[1.0]);
    }
}
