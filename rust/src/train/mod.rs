//! Native pure-Rust Quartet training (Algorithm 1 on the CPU backends).
//!
//! PR 1 gated the PJRT trainer behind the `xla` feature, which left the
//! default build able to quantize and serve but not *train* — the paper's
//! headline claim. This subsystem closes that gap with a training loop
//! driven entirely through the [`crate::kernels::Backend`] layer:
//!
//! * [`layer`] — [`QuantLinear`]: forward = fixed block Hadamard + QuEST
//!   MXFP4 quantization + the packed `gemm_mxfp4`; backward = randomized
//!   Hadamard + SR(3/4·x) gradient quantization (the `QuartetSr` path)
//!   with the QuEST trust mask applied as a straight-through gradient
//!   gate via the backend's fused masked gradient GEMM.
//! * [`model`] — [`MlpLm`]: an order-2 MLP language model over the
//!   Zipf–Markov corpus (token-pair embedding → quantized linear stack →
//!   vocab logits), with JSON checkpoints `serve::CpuPrefillEngine`
//!   consumes.
//! * [`transformer`] — [`TransformerLm`]: the Llama-style decoder
//!   (`arch: transformer`) — RMSNorm → causal rotary attention → SwiGLU
//!   blocks with all matmuls (tied vocab head included) on the same
//!   method axis; the workload shape the paper actually evaluates, and
//!   the substrate of the serving engine's KV-cached decode.
//! * [`optim`] — [`Adam`] with bias correction.
//! * [`dist`] — data-parallel training: N in-process workers over fixed
//!   logical shards of the global batch, synchronized by a
//!   [`GradReducer`] that all-reduces gradients either in f32 or
//!   MXFP4-compressed (unbiased SR through
//!   `Backend::reduce_mxfp4`, 4.25 vs 32 bits/value on the wire), with
//!   loss curves bit-identical at any worker count.
//! * [`topo`] — the other two axes of a 3D topology: Megatron-style
//!   tensor-sharded block matmuls (`ts` logical shards on `tp` physical
//!   ranks) whose partial sums cross the wire through
//!   reduce-scatter/all-gather collectives, and a 1F1B pipeline schedule
//!   (`pp` stages over contiguous block ranges, gradient shards as
//!   microbatches) with activations QDQ'd at every block boundary — loss
//!   curves bit-identical at any `(workers, tp, pp)` placement of a fixed
//!   `(seed, shards, ts, wire)`.
//! * [`trainer`] — [`train_native`] / [`train_native_transformer`]: the
//!   loops (batching, eval, divergence detection, the optional
//!   [`DistOptions`] axis) emitting
//!   [`crate::coordinator::runrecord::RunRecord`]s so `scaling::fit`
//!   consumes native runs exactly like PJRT sweeps.
//!
//! The method axis reproduces Table 3's ordering on CPU:
//! `f32` (exact) ≤ `mxfp8` (lossless baseline) ≤ `quartet` (QuEST fwd +
//! unbiased SR bwd) < `rtn` (naive unrotated RTN fwd+bwd, biased
//! gradients). Training uses Adam under a cosine learning-rate decay, so
//! the unbiased methods' late-run quantization noise averages out while
//! the naive baseline's bias floor persists.

pub mod dist;
pub mod layer;
pub mod model;
pub mod optim;
pub mod topo;
pub mod trainer;
pub mod transformer;

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

pub use dist::{CommsBytes, DistOptions, GradReducer, ReduceMode, Topology, DEFAULT_GRAD_SHARDS};
pub use layer::QuantLinear;
pub use model::MlpLm;
pub use optim::Adam;
pub use topo::{dist_loss_and_grads_topo_mlp, dist_loss_and_grads_topo_transformer};
pub use trainer::{train_native, train_native_transformer, NativeTrainOptions};
pub use transformer::{TransformerConfig, TransformerLm};

use crate::quant::format::MXFP4;

/// The MX-group alignment the native models are built around (the forward
/// contraction axes must tile into MXFP4 groups; NVFP4's 16-groups divide
/// it, so one constraint covers the whole method axis).
const GROUP: usize = MXFP4.group;

/// A trained native model of either architecture — what `repro serve`
/// loads from disk without being told which trainer produced it.
pub enum NativeModel {
    Mlp(MlpLm),
    Transformer(TransformerLm),
}

impl NativeModel {
    /// Load a native checkpoint, dispatching on its `kind` field
    /// (`native-mlp-lm` | `native-llama-lm`). The JSON — dominated by the
    /// serialized weights — is read and parsed exactly once.
    pub fn load(path: &Path) -> Result<NativeModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let j = crate::util::json::Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let loaded = match j.req("kind")?.as_str().unwrap_or("") {
            "native-mlp-lm" => NativeModel::Mlp(MlpLm::from_json(&j)?),
            "native-llama-lm" => NativeModel::Transformer(TransformerLm::from_json(&j)?),
            other => bail!(
                "{}: unknown checkpoint kind {other:?} (expected native-mlp-lm or \
                 native-llama-lm)",
                path.display()
            ),
        };
        Ok(loaded)
    }

    /// Write the checkpoint of whichever architecture this is.
    pub fn save(&self, path: &Path) -> Result<()> {
        match self {
            NativeModel::Mlp(m) => m.save(path),
            NativeModel::Transformer(m) => m.save(path),
        }
    }

    pub fn vocab(&self) -> usize {
        match self {
            NativeModel::Mlp(m) => m.cfg.vocab,
            NativeModel::Transformer(m) => m.cfg.vocab,
        }
    }

    pub fn arch_name(&self) -> &'static str {
        match self {
            NativeModel::Mlp(_) => "mlp",
            NativeModel::Transformer(_) => "transformer",
        }
    }
}

/// Precision recipe for the linear layers — the Table 3 method axis.
/// This is a thin alias for the crate's single method-axis enum
/// ([`crate::quant::format::Method`]); training consumes the full axis,
/// so no restriction applies here. The variants, `name()` registry and
/// `parse()` live in `quant::format`.
pub type TrainMethod = crate::quant::format::Method;

/// Shape of the native MLP language model. The model predicts token t+1
/// from the embeddings of tokens (t-1, t) — exactly the order-2 structure
/// the synthetic corpus carries.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    /// per-token embedding width; the first linear consumes 2·d_emb
    pub d_emb: usize,
    pub d_hidden: usize,
    /// extra d_hidden → d_hidden layers between the input and output
    /// projections (0 = two-layer MLP)
    pub n_hidden: usize,
    pub method: TrainMethod,
}

impl ModelConfig {
    /// MX-group alignment of the *forward* contraction axes — what the
    /// model structurally needs to run (serving included). Training
    /// additionally requires `vocab % 32 == 0` (the backward quantizes
    /// dy `[rows, vocab]`); `train_native` enforces that separately so a
    /// serving engine can carry any vocab.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (2 * self.d_emb) % GROUP == 0,
            "2*d_emb must be a multiple of {GROUP} (d_emb {})",
            self.d_emb
        );
        ensure!(
            self.d_hidden % GROUP == 0,
            "d_hidden must be a multiple of {GROUP} (got {})",
            self.d_hidden
        );
        ensure!(self.d_emb > 0 && self.d_hidden > 0 && self.vocab > 1, "degenerate shape");
        Ok(())
    }

    /// The extra trainability constraint on top of [`ModelConfig::validate`].
    pub fn validate_for_training(&self) -> Result<()> {
        self.validate()?;
        ensure!(
            self.vocab % GROUP == 0,
            "training quantizes the logit gradient [rows, vocab], so vocab must be a \
             multiple of {GROUP} (got {})",
            self.vocab
        );
        Ok(())
    }

    /// (d_out, d_in) of every linear layer, input → output order.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = vec![(self.d_hidden, 2 * self.d_emb)];
        dims.extend(std::iter::repeat((self.d_hidden, self.d_hidden)).take(self.n_hidden));
        dims.push((self.vocab, self.d_hidden));
        dims
    }

    /// Linear-layer parameter count (the N of the scaling law; embeddings
    /// excluded, matching the PJRT manifests).
    pub fn non_embedding_params(&self) -> usize {
        self.layer_dims().iter().map(|&(o, i)| o * i).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in TrainMethod::ALL {
            assert_eq!(TrainMethod::parse(m.name()).unwrap(), m);
        }
        assert!(TrainMethod::parse("bf16").is_err());
    }

    #[test]
    fn config_validation_catches_misalignment() {
        let ok = ModelConfig {
            vocab: 64,
            d_emb: 16,
            d_hidden: 128,
            n_hidden: 1,
            method: TrainMethod::Quartet,
        };
        ok.validate().unwrap();
        assert!(ModelConfig { d_emb: 8, ..ok.clone() }.validate().is_err());
        assert!(ModelConfig { d_hidden: 100, ..ok.clone() }.validate().is_err());
        // unaligned vocab is servable but not trainable
        let odd_vocab = ModelConfig { vocab: 100, ..ok.clone() };
        odd_vocab.validate().unwrap();
        assert!(odd_vocab.validate_for_training().is_err());
    }

    #[test]
    fn layer_dims_and_param_accounting() {
        let cfg = ModelConfig {
            vocab: 64,
            d_emb: 16,
            d_hidden: 128,
            n_hidden: 2,
            method: TrainMethod::F32,
        };
        let dims = cfg.layer_dims();
        assert_eq!(dims, vec![(128, 32), (128, 128), (128, 128), (64, 128)]);
        assert_eq!(
            cfg.non_embedding_params(),
            128 * 32 + 128 * 128 + 128 * 128 + 64 * 128
        );
    }
}
