//! Data-parallel native training: N in-process workers, each computing
//! gradients on its shard of the global batch, synchronized by a
//! [`GradReducer`] that all-reduces per-tensor gradients either exactly
//! (f32) or through the MXFP4 wire format via unbiased stochastic
//! rounding ([`crate::kernels::Backend::reduce_mxfp4`]) — the paper's
//! central claim (SR keeps FP4 gradients usable end to end) applied to
//! *communicating* gradients, not just computing with them.
//!
//! # Determinism model
//!
//! The global batch is always split into [`DistOptions::shards`] fixed,
//! equal, contiguous **logical shards**; `--workers N` only chooses how
//! many OS threads pick those shards up (contiguous balanced ranges).
//! Every per-shard quantity is keyed by the shard index, never by the
//! thread that ran it:
//!
//! * the model's own SR streams: each shard's forward/backward draws from
//!   [`shard_stream`]`(seed, step, shard)`;
//! * the reducer's compression streams: tensor `t`'s contribution from
//!   shard `p` is SR-quantized under a salt folded from
//!   `(seed, step, p, t)`;
//! * the reduction itself folds shard contributions element-wise in shard
//!   order (f32 addition in a fixed order).
//!
//! So the loss curve is a pure function of `(seed, shards, reduce)` and
//! is **bit-identical at any worker count** — the same invariant
//! [`crate::kernels::ParallelBackend`] pins for its thread count, lifted
//! one level up the stack. `tests/dist_training.rs` pins it for both
//! backends and both architectures.
//!
//! # Comms accounting
//!
//! Each step's all-reduce payload (one worker's full gradient in wire
//! format: 32 bits/value for f32, 4.25 for MXFP4) is accumulated by the
//! reducer, and the trainer records the classic ring all-reduce volume
//! `2·(W−1)·payload` in the run record (`comms_bytes_per_step`) — the
//! number `fig8_dist_scaling` sweeps against worker count.
//!
//! The model deliberately charges **one message per worker**, independent
//! of the shard count: a real deployment's worker sums its local shards
//! in f32 for free (they never cross a wire) and compresses the single
//! outgoing message. This simulation quantizes per *shard* instead —
//! that is a determinism device (it keeps the bits worker-count
//! invariant), not a wire requirement, so the accounting follows the
//! deployment, not the simulation's internal granularity.

use anyhow::{anyhow, ensure, Result};

use crate::kernels::Backend;
use crate::train::model::{Grads, MlpLm};
use crate::train::transformer::{TfBlockGrads, TfGrads, TransformerLm};
use crate::util::rng::Rng;

/// Default logical shard count (the determinism granularity): small
/// enough that per-shard forward passes stay efficient, large enough
/// that `--workers 4` parallelizes fully.
pub const DEFAULT_GRAD_SHARDS: usize = 4;

/// How per-shard gradients cross the (virtual) wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceMode {
    /// Exact element-wise f32 sum in shard order — the baseline, 32
    /// bits/value on the wire.
    F32,
    /// Each contribution is SR-quantized to packed MXFP4 (4.25
    /// bits/value: 4-bit codes + one E8M0 scale byte per 32) and decoded
    /// on the receive side; unbiased, so the reduced gradient estimates
    /// the f32 sum without bias.
    Mxfp4,
}

impl ReduceMode {
    pub fn name(self) -> &'static str {
        match self {
            ReduceMode::F32 => "f32",
            ReduceMode::Mxfp4 => "mxfp4",
        }
    }

    pub fn parse(s: &str) -> Result<ReduceMode> {
        match s {
            "f32" => Ok(ReduceMode::F32),
            "mxfp4" => Ok(ReduceMode::Mxfp4),
            other => Err(anyhow!("unknown reduce mode {other:?} (expected f32|mxfp4)")),
        }
    }

    /// Wire bits per gradient value.
    pub fn bits_per_value(self) -> f64 {
        match self {
            ReduceMode::F32 => 32.0,
            ReduceMode::Mxfp4 => 4.25,
        }
    }

    /// Wire bytes for a `values`-element tensor.
    pub fn payload_bytes(self, values: usize) -> f64 {
        values as f64 * self.bits_per_value() / 8.0
    }
}

/// The data-parallel axis of a native training run.
#[derive(Debug, Clone)]
pub struct DistOptions {
    /// OS worker threads; clamped to `shards` (extra workers would idle).
    pub workers: usize,
    /// Logical gradient shards per step — fixes the determinism
    /// granularity independently of `workers`.
    pub shards: usize,
    pub reduce: ReduceMode,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            workers: 1,
            shards: DEFAULT_GRAD_SHARDS,
            reduce: ReduceMode::F32,
        }
    }
}

impl DistOptions {
    /// Effective worker thread count.
    pub fn effective_workers(&self) -> usize {
        self.workers.max(1).min(self.shards.max(1))
    }

    /// The shard structure must tile the global batch exactly — unequal
    /// shards would break both the mean-of-means loss identity and the
    /// worker-count invariance.
    pub fn validate(&self, batch: usize) -> Result<()> {
        ensure!(self.shards >= 1, "need at least one gradient shard");
        ensure!(self.workers >= 1, "need at least one worker");
        ensure!(
            batch % self.shards == 0,
            "batch {} must be divisible by the shard count {} (equal shards are \
             what keeps the loss a mean of shard means)",
            batch,
            self.shards
        );
        Ok(())
    }
}

/// The tensor/pipeline axes of a native training run, layered on the same
/// logical/physical split as [`DistOptions`]: `ts` (tensor shards) is
/// **logical** — it fixes where weights/activations are sliced and where
/// the wire QDQ happens, and therefore the loss bits — while `tp` and
/// `pp` are **physical** — they choose thread placement and drive the
/// per-collective comms accounting, and must never change a bit of the
/// loss curve ([`crate::train::topo`] pins this).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Logical tensor shards: Megatron-style column/row splits of QKV, O
    /// and gate/up/down (transformer) or the hidden stack (MLP). Fixes
    /// the determinism granularity of the TP axis.
    pub ts: usize,
    /// Physical TP ranks (threads picking tensor shards up); clamped to
    /// `ts` like `workers` is to `shards`. Only affects placement and the
    /// reduce-scatter/all-gather byte accounting.
    pub tp: usize,
    /// Pipeline stages — contiguous balanced block ranges with 1F1B
    /// microbatching (one microbatch per gradient shard). `pp == 1` runs
    /// the same boundary math sequentially; stage placement never changes
    /// bits.
    pub pp: usize,
    /// How TP partial sums / gathered activations and PP boundary
    /// activations/gradients cross the wire when `ts > 1` (TP sites) or
    /// between blocks (PP boundary QDQ, applied at every interior
    /// boundary regardless of `pp` so stage placement stays logical).
    pub wire: ReduceMode,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { ts: 1, tp: 1, pp: 1, wire: ReduceMode::F32 }
    }
}

impl Topology {
    /// Effective physical TP rank count.
    pub fn effective_tp(&self) -> usize {
        self.tp.max(1).min(self.ts.max(1))
    }

    /// Axis sanity independent of any model shape (shape-dependent checks
    /// live with the architectures in `train::topo`).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.ts >= 1, "need at least one tensor shard");
        ensure!(self.tp >= 1, "need at least one TP rank");
        ensure!(self.pp >= 1, "need at least one pipeline stage");
        Ok(())
    }
}

/// Per-collective wire bytes of one training step under a [`Topology`]:
/// the DP gradient ring all-reduce, the two halves of every TP wire
/// all-reduce (reduce-scatter + all-gather), and the PP stage-boundary
/// point-to-point sends. Physical accounting only — `tp == 1` or
/// `pp == 1` contribute exactly zero bytes on their axis even though the
/// logical QDQ still runs (the same convention `workers == 1` uses for
/// the ring).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommsBytes {
    pub allreduce: f64,
    pub reduce_scatter: f64,
    pub all_gather: f64,
    pub p2p: f64,
}

impl CommsBytes {
    pub fn total(&self) -> f64 {
        self.allreduce + self.reduce_scatter + self.all_gather + self.p2p
    }
}

/// Splitmix-style fold of the run seed, step, shard and tensor labels
/// into one 64-bit salt; shared by the model-backward streams
/// (`tensor = MODEL_STREAM`), the reducer's compression streams, and the
/// topology wire-collective streams (`train::topo`, which offsets its
/// tensor labels past every reducer id).
pub(crate) fn fold_salt(seed: u64, step: u64, shard: u64, tensor: u64) -> u64 {
    let mut h = seed ^ 0x9e37_79b9_7f4a_7c15;
    for v in [step, shard, tensor] {
        h = (h ^ v.wrapping_mul(0xa076_1d64_78bd_642f))
            .rotate_left(23)
            .wrapping_mul(0xe703_7ed1_a0b4_28db);
        h ^= h >> 29;
    }
    h
}

/// Tensor label reserved for the per-shard model forward/backward stream
/// (reducer tensor ids start at 0 and stay well below this).
const MODEL_STREAM: u64 = u64::MAX;

/// Per-(seed, step, shard) RNG stream for one shard's forward/backward.
pub fn shard_stream(seed: u64, step: usize, shard: usize) -> Rng {
    Rng::new(fold_salt(seed, step as u64, shard as u64, MODEL_STREAM))
}

/// Modeled ring all-reduce volume for one step: every worker sends and
/// receives `(W−1)/W` of the payload in the reduce-scatter and again in
/// the all-gather, so the cluster moves `2·(W−1)·payload` bytes total.
/// One worker needs no wire at all.
pub fn ring_allreduce_bytes(workers: usize, payload_bytes: f64) -> f64 {
    if workers <= 1 {
        0.0
    } else {
        2.0 * (workers - 1) as f64 * payload_bytes
    }
}

/// MX-aligned view of a gradient tensor: natural `[rows, cols]` when the
/// contraction axis is 32-aligned, flattened `[1, len]` when only the
/// total length is, `None` when neither (the reducer then falls back to
/// the exact f32 path for that tensor).
fn mx_shape(rows: usize, cols: usize) -> Option<(usize, usize)> {
    let group = crate::quant::format::MXFP4.group;
    if cols % group == 0 {
        Some((rows, cols))
    } else if (rows * cols) % group == 0 {
        Some((1, rows * cols))
    } else {
        None
    }
}

/// All-reduces one parameter tensor at a time across the shard set;
/// constructed once per optimizer step so `payload_bytes` accumulates
/// exactly one worker's per-step gradient wire volume.
pub struct GradReducer<'a> {
    be: &'a dyn Backend,
    mode: ReduceMode,
    seed: u64,
    step: u64,
    /// wire bytes of one worker's full gradient payload this step
    pub payload_bytes: f64,
}

impl<'a> GradReducer<'a> {
    pub fn new(be: &'a dyn Backend, mode: ReduceMode, seed: u64, step: usize) -> GradReducer<'a> {
        GradReducer { be, mode, seed, step: step as u64, payload_bytes: 0.0 }
    }

    /// Reduce one tensor's per-shard contributions (each `[rows, cols]`)
    /// into `Σ_p weight·parts[p]`, folding in shard order. `tensor_id`
    /// distinguishes the SR compression streams between tensors of one
    /// step; shard index supplies the other axis, so the streams are
    /// per-(seed, step, shard, tensor) and never depend on which worker
    /// ran the shard.
    pub fn reduce(
        &mut self,
        parts: &[&[f32]],
        weight: f32,
        rows: usize,
        cols: usize,
        tensor_id: u64,
    ) -> Vec<f32> {
        let len = rows * cols;
        for part in parts {
            assert_eq!(part.len(), len, "gradient part shape mismatch");
        }
        match self.mode {
            ReduceMode::F32 => {
                self.payload_bytes += ReduceMode::F32.payload_bytes(len);
                self.sum_f32(parts, weight, len)
            }
            ReduceMode::Mxfp4 => match mx_shape(rows, cols) {
                Some((r, c)) => {
                    self.payload_bytes += ReduceMode::Mxfp4.payload_bytes(len);
                    // what crosses the wire is each shard's RAW gradient
                    // (that is what a worker would send); the shard weight
                    // is applied once to the decoded sum — still unbiased
                    // (E[w·ΣQ(vₚ)] = w·Σvₚ) and it avoids materializing a
                    // weighted copy of every shard tensor per step
                    let salts: Vec<u64> = (0..parts.len())
                        .map(|p| fold_salt(self.seed, self.step, p as u64, tensor_id))
                        .collect();
                    let mut acc = self.be.reduce_mxfp4(parts, r, c, &salts);
                    for a in acc.iter_mut() {
                        *a *= weight;
                    }
                    acc
                }
                // not MX-groupable in any view: ship it exact (and account
                // it at 32 bits/value — no silent discount)
                None => {
                    self.payload_bytes += ReduceMode::F32.payload_bytes(len);
                    self.sum_f32(parts, weight, len)
                }
            },
        }
    }

    fn sum_f32(&self, parts: &[&[f32]], weight: f32, len: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; len];
        for part in parts {
            for (a, &v) in acc.iter_mut().zip(*part) {
                *a += v * weight;
            }
        }
        acc
    }
}

/// Run `f(shard_index)` for every shard on `workers` scoped threads
/// (contiguous balanced shard ranges) and return the per-shard results in
/// shard order. Which worker ran a shard never affects its result, so
/// the output is worker-count invariant by construction.
pub(crate) fn run_sharded<T, F>(shards: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let w = workers.max(1).min(shards.max(1));
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(shards, || None);
    std::thread::scope(|scope| {
        let mut rest: &mut [Option<T>] = &mut slots;
        let mut s0 = 0usize;
        for wi in 0..w {
            let n = shards / w + usize::from(wi < shards % w);
            if n == 0 {
                continue;
            }
            let (chunk, next) = rest.split_at_mut(n);
            rest = next;
            let shard0 = s0;
            s0 += n;
            let f = &f;
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(shard0 + i));
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("shard computed")).collect()
}

/// One data-parallel MLP step: shard the `(ctx, tgt)` global batch,
/// compute per-shard `loss_and_grads` on worker threads, all-reduce.
/// Returns the global mean loss, the reduced gradients, and one worker's
/// gradient wire payload in bytes.
pub fn dist_loss_and_grads_mlp(
    model: &MlpLm,
    ctx: &[(u32, u32)],
    tgt: &[u32],
    d: &DistOptions,
    be: &dyn Backend,
    seed: u64,
    step: usize,
) -> (f64, Grads, f64) {
    let b = ctx.len();
    let shards = d.shards.max(1);
    assert_eq!(b % shards, 0, "batch must tile into shards (DistOptions::validate)");
    assert_eq!(tgt.len(), b);
    let per = b / shards;

    let results = run_sharded(shards, d.effective_workers(), |sh| {
        let lo = sh * per;
        let hi = lo + per;
        let mut rng = shard_stream(seed, step, sh);
        model.loss_and_grads(&ctx[lo..hi], &tgt[lo..hi], be, &mut rng)
    });

    let loss = results.iter().map(|(l, _)| *l).sum::<f64>() / shards as f64;
    let weight = 1.0 / shards as f32;
    let mut reducer = GradReducer::new(be, d.reduce, seed, step);

    let emb_parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.tok_emb.as_slice()).collect();
    let tok_emb = reducer.reduce(&emb_parts, weight, model.cfg.vocab, model.cfg.d_emb, 0);
    let mut layers = Vec::with_capacity(model.layers.len());
    for (li, layer) in model.layers.iter().enumerate() {
        let parts: Vec<&[f32]> =
            results.iter().map(|(_, g)| g.layers[li].as_slice()).collect();
        layers.push(reducer.reduce(&parts, weight, layer.d_out, layer.d_in, (li + 1) as u64));
    }
    (loss, Grads { tok_emb, layers }, reducer.payload_bytes)
}

/// One data-parallel transformer step over `toks [b, seq+1]` windows;
/// the transformer twin of [`dist_loss_and_grads_mlp`].
pub fn dist_loss_and_grads_transformer(
    model: &TransformerLm,
    toks: &[u32],
    b: usize,
    d: &DistOptions,
    be: &dyn Backend,
    seed: u64,
    step: usize,
) -> (f64, TfGrads, f64) {
    let shards = d.shards.max(1);
    assert_eq!(b % shards, 0, "batch must tile into shards (DistOptions::validate)");
    let win = model.cfg.seq + 1;
    assert_eq!(toks.len(), b * win);
    let per = b / shards;

    let results = run_sharded(shards, d.effective_workers(), |sh| {
        let lo = sh * per * win;
        let hi = lo + per * win;
        let mut rng = shard_stream(seed, step, sh);
        model.loss_and_grads(&toks[lo..hi], per, be, &mut rng)
    });

    let loss = results.iter().map(|(l, _)| *l).sum::<f64>() / shards as f64;
    let weight = 1.0 / shards as f32;
    let cfg = &model.cfg;
    let mut reducer = GradReducer::new(be, d.reduce, seed, step);

    // tensor ids mirror the Adam slot order: tok_emb, then 9 per block,
    // then final_norm — stable labels for the compression streams
    let emb_parts: Vec<&[f32]> = results.iter().map(|(_, g)| g.tok_emb.as_slice()).collect();
    let tok_emb = reducer.reduce(&emb_parts, weight, cfg.vocab, cfg.d_model, 0);

    let mut blocks = Vec::with_capacity(model.blocks.len());
    for bi in 0..model.blocks.len() {
        let base = 1 + bi as u64 * 9;
        let pick = |sel: fn(&TfBlockGrads) -> &Vec<f32>| -> Vec<&[f32]> {
            results.iter().map(|(_, g)| sel(&g.blocks[bi]).as_slice()).collect()
        };
        blocks.push(TfBlockGrads {
            attn_norm: reducer.reduce(&pick(|g| &g.attn_norm), weight, 1, cfg.d_model, base),
            wq: reducer.reduce(&pick(|g| &g.wq), weight, cfg.d_model, cfg.d_model, base + 1),
            wk: reducer.reduce(&pick(|g| &g.wk), weight, cfg.d_model, cfg.d_model, base + 2),
            wv: reducer.reduce(&pick(|g| &g.wv), weight, cfg.d_model, cfg.d_model, base + 3),
            wo: reducer.reduce(&pick(|g| &g.wo), weight, cfg.d_model, cfg.d_model, base + 4),
            mlp_norm: reducer.reduce(&pick(|g| &g.mlp_norm), weight, 1, cfg.d_model, base + 5),
            w_gate: reducer.reduce(&pick(|g| &g.w_gate), weight, cfg.d_ff, cfg.d_model, base + 6),
            w_up: reducer.reduce(&pick(|g| &g.w_up), weight, cfg.d_ff, cfg.d_model, base + 7),
            w_down: reducer.reduce(&pick(|g| &g.w_down), weight, cfg.d_model, cfg.d_ff, base + 8),
        });
    }
    let fin_parts: Vec<&[f32]> =
        results.iter().map(|(_, g)| g.final_norm.as_slice()).collect();
    let final_norm = reducer.reduce(
        &fin_parts,
        weight,
        1,
        cfg.d_model,
        1 + model.blocks.len() as u64 * 9,
    );
    (loss, TfGrads { tok_emb, blocks, final_norm }, reducer.payload_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ScalarBackend;

    #[test]
    fn reduce_mode_parse_and_bits() {
        assert_eq!(ReduceMode::parse("f32").unwrap(), ReduceMode::F32);
        assert_eq!(ReduceMode::parse("mxfp4").unwrap(), ReduceMode::Mxfp4);
        assert!(ReduceMode::parse("fp8").is_err());
        assert_eq!(ReduceMode::F32.bits_per_value(), 32.0);
        assert_eq!(ReduceMode::Mxfp4.bits_per_value(), 4.25);
        // 64 values: 32 bytes of codes/2 + 2 scale bytes = 34
        assert_eq!(ReduceMode::Mxfp4.payload_bytes(64), 34.0);
        assert_eq!(ReduceMode::F32.payload_bytes(64), 256.0);
    }

    #[test]
    fn ring_volume_zero_for_single_worker() {
        assert_eq!(ring_allreduce_bytes(1, 1000.0), 0.0);
        assert_eq!(ring_allreduce_bytes(2, 1000.0), 2000.0);
        assert_eq!(ring_allreduce_bytes(4, 1000.0), 6000.0);
    }

    #[test]
    fn validate_enforces_shard_tiling() {
        let d = DistOptions { workers: 4, shards: 4, reduce: ReduceMode::F32 };
        d.validate(32).unwrap();
        assert!(d.validate(30).is_err());
        assert!(DistOptions { shards: 0, ..d.clone() }.validate(32).is_err());
        assert_eq!(DistOptions { workers: 9, ..d }.effective_workers(), 4);
    }

    #[test]
    fn mx_shape_prefers_natural_then_flat() {
        assert_eq!(mx_shape(4, 64), Some((4, 64)));
        assert_eq!(mx_shape(32, 16), Some((1, 512)));
        assert_eq!(mx_shape(3, 5), None);
    }

    #[test]
    fn shard_streams_distinct_and_stable() {
        let mut a = shard_stream(1, 2, 0);
        let mut b = shard_stream(1, 2, 1);
        let mut c = shard_stream(1, 3, 0);
        let x = a.next_u64();
        assert_ne!(x, b.next_u64());
        assert_ne!(x, c.next_u64());
        assert_eq!(x, shard_stream(1, 2, 0).next_u64());
    }

    #[test]
    fn run_sharded_output_is_in_shard_order_at_any_worker_count() {
        for w in [1usize, 2, 3, 5, 9] {
            let got = run_sharded(5, w, |s| s * 10);
            assert_eq!(got, vec![0, 10, 20, 30, 40], "workers {w}");
        }
    }

    #[test]
    fn f32_reduce_is_weighted_shard_ordered_sum() {
        let be = ScalarBackend;
        let a = vec![1.0f32; 32];
        let b = vec![3.0f32; 32];
        let mut r = GradReducer::new(&be, ReduceMode::F32, 0, 1);
        let out = r.reduce(&[&a, &b], 0.5, 1, 32, 0);
        assert!(out.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert_eq!(r.payload_bytes, 32.0 * 4.0);
    }

    #[test]
    fn mxfp4_reduce_deterministic_per_step_and_tensor() {
        let be = ScalarBackend;
        let mut rng = Rng::new(5);
        let a = rng.gaussian_vec(2 * 32, 1.0);
        let b = rng.gaussian_vec(2 * 32, 1.0);
        let go = |step: usize, tensor: u64| {
            let mut r = GradReducer::new(&be, ReduceMode::Mxfp4, 7, step);
            r.reduce(&[a.as_slice(), b.as_slice()], 0.5, 2, 32, tensor)
        };
        assert_eq!(go(1, 0), go(1, 0));
        assert_ne!(go(1, 0), go(2, 0), "step must advance the SR streams");
        assert_ne!(go(1, 0), go(1, 1), "tensors must not share SR streams");
    }
}
