//! Shared experiment harness for `benches/*` and the `repro experiments`
//! CLI: common paths, kernel-shape tables, and the per-figure helpers
//! that turn raw measurements into the paper's rows/series.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Resolve an artifact/run directory: env override first, then the crate
/// dir (`rust/<leaf>`), then the workspace root (`<repo>/<leaf>`), and
/// finally a cwd-relative `./<leaf>` so benches and binaries still work
/// outside `cargo bench` contexts (installed binaries, CI checkouts).
fn resolve_root(env_key: &str, leaf: &str) -> PathBuf {
    if let Ok(p) = std::env::var(env_key) {
        return PathBuf::from(p);
    }
    let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let in_crate = crate_dir.join(leaf);
    if in_crate.exists() {
        return in_crate;
    }
    if let Some(ws) = crate_dir.parent() {
        let in_ws = ws.join(leaf);
        if in_ws.exists() {
            return in_ws;
        }
    }
    PathBuf::from(".").join(leaf)
}

/// Artifact directory (`QUARTET_ARTIFACTS` env override).
pub fn artifacts_root() -> PathBuf {
    resolve_root("QUARTET_ARTIFACTS", "artifacts")
}

/// Run-record directory (`QUARTET_RUNS` env override).
pub fn runs_root() -> PathBuf {
    resolve_root("QUARTET_RUNS", "runs")
}

/// Llama linear-layer shapes (m = batch·seq at B=64, S=512 as in §5;
/// n/k from the model family). Fig 3(a,b)/Fig 5 sweep these.
/// (label, m, n, k)
pub fn llama_linear_shapes() -> Vec<(&'static str, usize, usize, usize)> {
    vec![
        // scaled-down testbed shapes (keep bench wall-time sane on CPU)
        ("30M qkv  (d=640)", 1024, 640, 640),
        ("200M qkv (d=1280)", 1024, 1280, 1280),
        ("7B qkv   (d=4096)", 256, 4096, 4096),
        ("7B mlp-up (4096→11008)", 256, 11008, 4096),
        ("7B mlp-dn (11008→4096)", 256, 4096, 11008),
    ]
}

/// FLOPs of one m×n×k GEMM.
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Geometric mean (for aggregating per-shape speedups, as Fig 3 does
/// across a transformer block).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// One backend × kernel aggregate row from a kernel bench
/// (`fig3_kernel_speedup`): geomean throughput across the shape sweep,
/// with the decode-once GEMM rows also carrying their speedup over the
/// ScalarBackend baseline so `repro check-records` can gate the claim.
#[derive(Debug, Clone)]
pub struct KernelRecord {
    pub bench: String,
    /// Kernel axis: `quantize` | `decode` | `hadamard` | `gemm` | `gemm_predec`.
    pub kernel: String,
    /// Stable backend name (`scalar` | `parallel` | `simd` | `parallel+simd`).
    pub backend: String,
    /// Human-facing backend description incl. detected ISA, e.g. `simd(avx2)`.
    pub backend_detail: String,
    /// Number of shapes aggregated into the geomeans.
    pub shapes: usize,
    pub gflops: f64,
    pub gbps: f64,
    /// Geomean speedup over ScalarBackend on the same kernel (absent for
    /// the scalar rows themselves).
    pub speedup_vs_scalar: Option<f64>,
}

impl KernelRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("bench", Json::str(&self.bench)),
            ("kernel", Json::str(&self.kernel)),
            ("backend", Json::str(&self.backend)),
            ("backend_detail", Json::str(&self.backend_detail)),
            ("shapes", Json::num(self.shapes as f64)),
            ("gflops", Json::num(self.gflops)),
            ("gbps", Json::num(self.gbps)),
        ];
        if let Some(s) = self.speedup_vs_scalar {
            pairs.push(("speedup_vs_scalar", Json::num(s)));
        }
        Json::from_pairs(pairs)
    }

    /// Write `{bench}_{kernel}_{backend}.json` into `dir` (created if
    /// missing); returns the path. `+` in backend names is kept as-is —
    /// it is filename-safe everywhere we run.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(format!("{}_{}_{}.json", self.bench, self.kernel, self.backend));
        std::fs::write(&path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// Paper-reported reference rows, kept next to the code that regenerates
/// them so every bench prints paper-vs-measured (EXPERIMENTS.md quotes
/// these).
pub mod paper {
    /// Table 3 validation losses at 30M params (ratio → loss) for Quartet.
    pub const TABLE3_QUARTET: [(f64, f64); 5] =
        [(25.0, 3.500), (50.0, 3.382), (100.0, 3.299), (200.0, 3.244), (400.0, 3.205)];

    /// Table 3 efficiency factors.
    pub const TABLE3_EFF: [(&str, f64, f64); 3] = [
        ("quartet", 0.64, 0.94),
        ("luq_int4", 0.50, 0.15),
        ("luq_fp4", 0.01, 0.09),
    ];

    /// Table 2 rows: (method, eff_n, mse, eff_d*, misalignment).
    pub const TABLE2: [(&str, f64, f64, f64, f64); 4] = [
        ("sr-absmax", 0.44, 2.84e-2, 0.85, 0.0),
        ("rtn-absmax", 0.61, 1.40e-2, 0.83, 9.3e-3),
        ("quest", 0.65, 1.35e-2, 0.18, 1.3e-2),
        ("rtn-absmax-pma", 0.61, 1.42e-2, 0.83, 2.8e-5),
    ];

    /// Fig 3 headline speedups vs FP8 (forward, backward) and vs BF16.
    pub const FIG3_VS_FP8: (f64, f64) = (2.4, 1.6);
    pub const FIG3_VS_BF16: (f64, f64) = (4.0, 2.3);

    /// Fig 6: prefill speedup plateaus at 1.41x by batch 128.
    pub const FIG6_PEAK: f64 = 1.41;

    /// Table 7: C4 perplexity, 7B — BF16 / QuaRot-PTQ / Quartet.
    pub const TABLE7: (f64, f64, f64) = (16.40, 18.19, 17.77);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn kernel_record_json_shape() {
        let mut rec = KernelRecord {
            bench: "fig3_kernel_speedup".to_string(),
            kernel: "gemm_predec".to_string(),
            backend: "parallel+simd".to_string(),
            backend_detail: "parallel+simd(avx2)".to_string(),
            shapes: 5,
            gflops: 1.25,
            gbps: 3.5,
            speedup_vs_scalar: Some(2.4),
        };
        let s = rec.to_json().to_string_pretty();
        assert!(s.contains("\"kernel\": \"gemm_predec\""));
        assert!(s.contains("\"speedup_vs_scalar\": 2.4"));
        rec.speedup_vs_scalar = None;
        assert!(!rec.to_json().to_string_pretty().contains("speedup_vs_scalar"));
    }

    #[test]
    fn kernel_record_save_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kernel_rec_{}", std::process::id()));
        let rec = KernelRecord {
            bench: "t".to_string(),
            kernel: "decode".to_string(),
            backend: "simd".to_string(),
            backend_detail: "simd(scalar)".to_string(),
            shapes: 1,
            gflops: 0.5,
            gbps: 1.0,
            speedup_vs_scalar: None,
        };
        let path = rec.save(&dir).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "t_decode_simd.json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"backend_detail\": \"simd(scalar)\""));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shapes_are_mx_group_aligned() {
        for (_, m, n, k) in llama_linear_shapes() {
            assert_eq!(m % 32, 0);
            assert_eq!(n % 32, 0);
            assert_eq!(k % 32, 0);
        }
    }
}
