"""Block Hadamard transforms (L2, build-time only).

Quartet applies the Hadamard transform at the *same* granularity as the
MXFP4 scale groups (g = 32): the forward pass uses the fixed normalized
H_32, the backward pass the *randomized* block Hadamard — a Rademacher
sign diagonal followed by H_32 — with the same randomness on both GEMM
operands so the rotation cancels in the contraction while decorrelating
quantization errors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .formats import MX_GROUP


@functools.lru_cache(maxsize=None)
def hadamard_matrix(g: int = MX_GROUP) -> np.ndarray:
    """Normalized Sylvester Hadamard matrix H_g (g a power of two).

    H_g @ H_g.T == I, so the inverse transform is the transpose (H is
    symmetric for Sylvester construction, hence also self-inverse).
    """
    if g & (g - 1) or g <= 0:
        raise ValueError(f"Hadamard size must be a power of two, got {g}")
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < g:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(g)).astype(np.float32)


def block_hadamard(x, g: int = MX_GROUP):
    """Apply H_g to each contiguous group of g elements along the last axis.

    The g x g matmul shape is exactly what the Pallas kernel feeds the MXU;
    here it constant-folds into the lowered HLO.
    """
    hm = jnp.asarray(hadamard_matrix(g))
    xg = x.reshape(*x.shape[:-1], x.shape[-1] // g, g)
    return (xg @ hm).reshape(x.shape)


def block_hadamard_inv(x, g: int = MX_GROUP):
    """Inverse block transform (H_g is orthogonal; Sylvester H is symmetric,
    so this equals the forward transform — kept separate for readability)."""
    hm = jnp.asarray(hadamard_matrix(g)).T
    xg = x.reshape(*x.shape[:-1], x.shape[-1] // g, g)
    return (xg @ hm).reshape(x.shape)


def rademacher_signs(key, d: int):
    """±1 sign vector for the randomized transform (shared per GEMM pair)."""
    return jnp.where(jax.random.bernoulli(key, 0.5, (d,)), 1.0, -1.0).astype(jnp.float32)


def randomized_block_hadamard(x, signs, g: int = MX_GROUP):
    """Ĥ_g(x, ξ) = H_g · diag(ξ) · x per block along the last axis.

    ``signs`` has length x.shape[-1]. Applying the same signs to both GEMM
    operands keeps the contraction exact: (H D g)·(H D w) = g·w per block.
    """
    return block_hadamard(x * signs, g)


def randomized_block_hadamard_inv(y, signs, g: int = MX_GROUP):
    """Inverse of the randomized transform: diag(ξ) · H_g^{-1} · y."""
    return block_hadamard_inv(y, g) * signs
