"""Quartet quantized linear layer (Algorithm 1) + the baseline method zoo.

One ``custom_vjp`` primitive, ``quant_linear``, parameterized by a static
``Method`` (forward-quantizer id, backward-quantizer id). All three GEMMs
of a linear layer — forward ``y = XqWq^T``, input-gradient ``dX = G Wq``
and weight-gradient ``dW = G^T Xq`` — run on quantized operands.

Methods (Table 3 of the paper):

==============  =====================================  =========================
id              forward                                backward
==============  =====================================  =========================
``bf16``        none                                   exact
``fp8``         MXFP8 E4M3 (g=32)                      MXFP8 E4M3
``quartet``     H32 + QuEST RTN MXFP4 + trust mask     Ĥ32 + SR(3/4·) MXFP4,
                                                       16/9 rescale, masks
``rtn``         H32 + AbsMax RTN MXFP4                 H32 + AbsMax RTN MXFP4
``sr``          H32 + AbsMax SR MXFP4                  Ĥ32 + SR(3/4·) MXFP4
``rtn_pma``     as ``rtn``                             RTN with E[S] PMA scale
``luq_int4``    AbsMax RTN INT4                        LUQ stochastic INT4
``luq_fp4``     AbsMax RTN MXFP4 (no Hadamard)         LUQ log-grid FP4
``jetfire_fp4`` 32x32 2-D block RTN FP4                32x32 2-D block RTN FP4
``halo_fp4``    H32 + per-tensor RTN FP4               H32 + per-tensor RTN FP4
``lss_int4``    H32 + INT4 RTN (LSQ-calibrated)        leverage-score sampled
                                                       2-component INT4 SR
==============  =====================================  =========================

Shapes: ``x: [T, din]`` (callers flatten batch·seq into T), ``w: [dout,
din]``, output ``[T, dout]``. T, din, dout must all be multiples of 32 —
the MX group size; the model configs guarantee this.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import formats as F
from .hadamard import (
    block_hadamard,
    block_hadamard_inv,
    rademacher_signs,
    randomized_block_hadamard,
)

# PMA correction constant E[S] for RTN-AbsMax MXFP4 over Hadamard-rotated
# Gaussian groups of 32 (the "RTN AbsMax PMA" row of Table 2); measured by
# rust `analysis::pma` and pinned here (see rust/src/analysis/alignment.rs).
RTN_PMA_SCALE = 1.0090


class Method(NamedTuple):
    """Static (hashable) quantization configuration for quant_linear."""

    fwd: str
    bwd: str
    use_pallas: bool = False


METHODS = {
    "bf16": Method("none", "exact"),
    "fp8": Method("fp8", "fp8"),
    "quartet": Method("quest", "quartet_sr"),
    "quartet_pallas": Method("quest", "quartet_sr", use_pallas=True),
    "rtn": Method("rtn", "rtn"),
    "sr": Method("sr", "quartet_sr"),
    "rtn_pma": Method("rtn", "rtn_pma"),
    # forward-only (QAT) ablations: quantized forward, exact backward
    "quest_fwd": Method("quest", "exact"),
    "rtn_fwd": Method("rtn", "exact"),
    "sr_fwd": Method("sr", "exact"),
    # backward-only ablations: exact forward, quantized backward
    "sr_bwd": Method("none", "quartet_sr"),
    "rtn_bwd": Method("none", "rtn"),
    "rtn_pma_bwd": Method("none", "rtn_pma"),
    # Table 3 baselines
    "luq_int4": Method("int4", "luq_int4"),
    "luq_fp4": Method("fp4_plain", "luq_fp4"),
    "jetfire_fp4": Method("jetfire", "jetfire"),
    "halo_fp4": Method("halo", "halo"),
    "lss_int4": Method("lss", "lss"),
}


# ---------------------------------------------------------------------------
# forward quantizers: x -> (q, trust_mask, hadamard_domain?)
# ---------------------------------------------------------------------------


def _fwd_quant(t, method: Method, key):
    """Quantize one forward operand. Returns (q, mask, in_h_domain)."""
    fid = method.fwd
    if fid == "none":
        return t, None, False
    if fid == "fp8":
        return F.mxfp8_rtn(t), None, False
    if fid == "quest":
        if method.use_pallas:
            from .kernels.quantize import quest_fused_pallas

            q, m = quest_fused_pallas(t)
        else:
            q, m = F.quest_quantize(block_hadamard(t))
        return q, m, True
    if fid == "rtn":
        return F.mxfp4_rtn(block_hadamard(t)), None, True
    if fid == "sr":
        u = jax.random.uniform(key, t.shape)
        # The paper's SR-AbsMax *forward* keeps plain absmax scaling with SR
        # on the grid (prescale=1; the absmax e8m0 scale already prevents
        # clipping, so SR stays unbiased without range compensation).
        return F.mxfp4_sr(block_hadamard(t), u, prescale=1.0), None, True
    if fid == "int4":
        return F.int4_rtn(t), None, False
    if fid == "fp4_plain":
        return F.mxfp4_rtn(t), None, False
    if fid == "jetfire":
        return F.jetfire_fp4(t), None, False
    if fid == "halo":
        return F.halo_fp4(block_hadamard(t)), None, True
    if fid == "lss":
        return F.int4_rtn(block_hadamard(t)), None, True
    raise ValueError(f"unknown forward quantizer {fid!r}")


# ---------------------------------------------------------------------------
# backward GEMM helper: quantize (g, op) along the contraction axis, multiply
# ---------------------------------------------------------------------------


def _bwd_gemm(g2d, op2d, method: Method, key):
    """Compute ``g2d @ op2d.T`` with both operands quantized per method.bwd.

    ``g2d: [R, C]``, ``op2d: [S, C]`` — contraction along C (the axis that
    carries the MX groups / Hadamard blocks). Returns ``[R, S]``.
    """
    bid = method.bwd
    if bid == "exact":
        return g2d @ op2d.T
    if bid == "fp8":
        return F.mxfp8_rtn(g2d) @ F.mxfp8_rtn(op2d).T
    if bid == "quartet_sr":
        c = g2d.shape[-1]
        ks, kg, ko = jax.random.split(key, 3)
        signs = rademacher_signs(ks, c)
        if method.use_pallas:
            from .kernels.gemm import mxfp4_matmul_pallas
            from .kernels.quantize import sr_fused_pallas

            gq = sr_fused_pallas(g2d, signs, jax.random.uniform(kg, g2d.shape))
            oq = sr_fused_pallas(op2d, signs, jax.random.uniform(ko, op2d.shape))
            return (16.0 / 9.0) * mxfp4_matmul_pallas(gq, oq)
        gh = randomized_block_hadamard(g2d, signs)
        oh = randomized_block_hadamard(op2d, signs)
        gq = F.mxfp4_sr(gh, jax.random.uniform(kg, g2d.shape))
        oq = F.mxfp4_sr(oh, jax.random.uniform(ko, op2d.shape))
        return (16.0 / 9.0) * (gq @ oq.T)
    if bid in ("rtn", "rtn_pma"):
        gq = F.mxfp4_rtn(block_hadamard(g2d))
        oq = F.mxfp4_rtn(block_hadamard(op2d))
        out = gq @ oq.T
        if bid == "rtn_pma":
            out = out * (RTN_PMA_SCALE ** 2)
        return out
    if bid == "luq_int4":
        kg, ko = jax.random.split(key)
        gq = F.luq_int4(g2d, jax.random.uniform(kg, g2d.shape))
        oq = F.int4_rtn(op2d)
        return gq @ oq.T
    if bid == "luq_fp4":
        kg, ko = jax.random.split(key)
        gq = F.luq_fp4(g2d, jax.random.uniform(kg, g2d.shape))
        oq = F.mxfp4_rtn(op2d)
        return gq @ oq.T
    if bid == "jetfire":
        return F.jetfire_fp4(g2d) @ F.jetfire_fp4(op2d).T
    if bid == "halo":
        return F.halo_fp4(block_hadamard(g2d)) @ F.halo_fp4(block_hadamard(op2d)).T
    if bid == "lss":
        return _lss_bwd_gemm(g2d, op2d, key)
    raise ValueError(f"unknown backward quantizer {bid!r}")


def _lss_bwd_gemm(g2d, op2d, key):
    """LSS (Xi et al. 2023) INT4 backward, simplified.

    Bit-splitting: G ≈ Q1 + Q2 with Q1 = SR-INT4(G) and Q2 = SR-INT4 of the
    residual, where the residual pass is only applied to the half of the
    rows with the largest leverage scores (row norms) — the "leverage score
    sampled" structured-sparsity trick. Unbiasedness holds per component;
    the variance blow-up on small rows is what destabilizes long runs
    (observed in Table 3 as NaNs).
    """
    kg1, kg2, ko = jax.random.split(key, 3)
    q1 = F.int4_sr(g2d, jax.random.uniform(kg1, g2d.shape))
    resid = g2d - q1
    norms = jnp.sum(resid * resid, axis=-1)
    med = jnp.median(norms)
    keep = (norms >= med).astype(g2d.dtype)[:, None]
    q2 = F.int4_sr(resid * keep * 2.0, jax.random.uniform(kg2, g2d.shape)) * 0.5
    gq = q1 + q2
    oq = F.int4_rtn(op2d)
    return gq @ oq.T


# ---------------------------------------------------------------------------
# the custom_vjp primitive
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def quant_linear(x, w, key, method: Method):
    """y = quant(x) @ quant(w).T with quantized backward — Algorithm 1."""
    y, _ = _qlin_fwd(x, w, key, method)
    return y


def _qlin_fwd(x, w, key, method: Method):
    kx, kw, kb = jax.random.split(key, 3)
    xq, mx, _ = _fwd_quant(x, method, kx)
    wq, mw, _ = _fwd_quant(w, method, kw)
    if method.use_pallas and method.fwd == "quest":
        from .kernels.gemm import mxfp4_matmul_pallas

        y = mxfp4_matmul_pallas(xq, wq)
    else:
        y = xq @ wq.T
    # Residuals: quantized operands (what the backward GEMMs consume per
    # Algorithm 1 — W_q and X_q, not the full-precision tensors), the trust
    # masks, and the backward randomness key.
    return y, (xq, wq, mx, mw, kb)


def _qlin_bwd(method: Method, res, dy):
    xq, wq, mx, mw, key = res
    kdx, kdw = jax.random.split(key)

    # dX = dy @ Wq — contraction over dout (last axis of both operands).
    dxh = _bwd_gemm(dy, wq.T, method, kdx)  # [T, din(_h)]
    # dW = dy^T @ Xq — contraction over tokens T.
    dwh = _bwd_gemm(dy.T, xq.T, method, kdw)  # [dout, din(_h)]

    if method.fwd == "quest":
        # Clip-aware STE: mask in the Hadamard domain, then invert H_g.
        dx = block_hadamard_inv(dxh * mx)
        dw = block_hadamard_inv(dwh * mw)
    elif method.fwd in ("rtn", "sr", "halo", "lss"):
        # Forward used a Hadamard rotation (no trust mask): plain STE in the
        # rotated space, then rotate back.
        dx = block_hadamard_inv(dxh)
        dw = block_hadamard_inv(dwh)
    else:
        dx, dw = dxh, dwh

    return dx, dw, np.zeros(key.shape, jax.dtypes.float0)


def _qlin_fwd_rule(x, w, key, method: Method):
    y, res = _qlin_fwd(x, w, key, method)
    return y, res


quant_linear.defvjp(_qlin_fwd_rule, _qlin_bwd)


def quartet_linear(x, w, key):
    """Convenience wrapper: the paper's headline configuration."""
    return quant_linear(x, w, key, METHODS["quartet"])
