"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering.

Never imported at runtime — the rust coordinator only consumes the HLO
text + manifest.json artifacts this package emits.
"""
