"""Float32 numpy twin of the rust native transformer forward
(`rust/src/train/transformer.rs`) — the reference that generates
``rust/tests/data/transformer_vectors.json``.

Every operation mirrors the rust implementation op-for-op in float32
(quantizers, Hadamard butterflies, RMSNorm's f64 mean-square, rotary,
SwiGLU, causal softmax with f64 normalizer), so the two sides agree to
float-ulp accumulation — the golden test compares with a small relative
tolerance and an outlier allowance for the rare group whose quantization
boundary sits within libm-ulp distance (see the regen notes there).

Pure numpy: no jax dependency, usable anywhere the generator runs.
"""

from __future__ import annotations

import numpy as np

MX_GROUP = 32
E2M1_MAX = np.float32(6.0)
QUEST_ALPHA = np.float32(2.925)
RMS_EPS = 1e-6
ROPE_THETA = np.float32(10000.0)
E8M0_MIN_EXP = -98


def f32(x):
    return np.asarray(x, dtype=np.float32)


def e2m1_rtn(x):
    """RTN to the E2M1 grid, ties away from zero, clamp ±6 (f32 twin)."""
    x = f32(x)
    a = np.abs(x)
    step = np.where(a < 2.0, np.float32(0.5),
                    np.where(a < 4.0, np.float32(1.0), np.float32(2.0))).astype(np.float32)
    q = (np.floor(a / step + np.float32(0.5)) * step).astype(np.float32)
    q = np.minimum(q, E2M1_MAX)
    return (np.where(x < 0, -q, q)).astype(np.float32)


def e8m0_scale(amax, target):
    """2^ceil(log2(amax/target)), exponent clamped to the E8M0 range."""
    safe = np.maximum(f32(amax), np.float32(2.0 ** E8M0_MIN_EXP))
    e = np.ceil(np.log2(safe / np.float32(target)))
    e = np.clip(e, E8M0_MIN_EXP, 127)
    return np.exp2(e).astype(np.float32)


def mxfp4_rtn(x):
    """AbsMax MXFP4 quant-dequant per 1x32 group along the last axis."""
    x = f32(x)
    xg = x.reshape(-1, MX_GROUP)
    s = e8m0_scale(np.max(np.abs(xg), axis=1, keepdims=True), E2M1_MAX)
    return (e2m1_rtn(xg / s) * s).astype(np.float32).reshape(x.shape)


def quest_quantize(x):
    """QuEST MXFP4: RMSE clip, best of the two neighbouring binades
    (f64 MSE comparison, like the rust quest_scale)."""
    x = f32(x)
    xg = x.reshape(-1, MX_GROUP)
    ms = np.sum(xg.astype(np.float32) * xg, axis=1, keepdims=True, dtype=np.float32)
    rms = np.sqrt(ms / np.float32(MX_GROUP) + np.float32(1e-20)).astype(np.float32)
    clip = QUEST_ALPHA * rms
    e = np.log2(np.maximum(clip / E2M1_MAX, np.float32(2.0 ** E8M0_MIN_EXP)))
    lo = np.exp2(np.clip(np.floor(e), E8M0_MIN_EXP, 127)).astype(np.float32)
    hi = np.exp2(np.clip(np.ceil(e), E8M0_MIN_EXP, 127)).astype(np.float32)
    q_lo = (e2m1_rtn(xg / lo) * lo).astype(np.float32)
    q_hi = (e2m1_rtn(xg / hi) * hi).astype(np.float32)
    mse_lo = np.sum((q_lo - xg).astype(np.float64) ** 2, axis=1, keepdims=True)
    mse_hi = np.sum((q_hi - xg).astype(np.float64) ** 2, axis=1, keepdims=True)
    use_lo = mse_lo <= mse_hi
    q = np.where(use_lo, q_lo, q_hi).astype(np.float32)
    s = np.where(use_lo, lo, hi).astype(np.float32)
    mask = np.abs(xg) <= s * E2M1_MAX
    return q.reshape(x.shape), mask.reshape(x.shape)


def e4m3(x):
    x = f32(x)
    a = np.abs(x)
    e = np.floor(np.log2(np.maximum(a, np.float32(1e-38))))
    e = np.maximum(e, np.float32(-6.0))
    ulp = np.exp2(e - np.float32(3.0)).astype(np.float32)
    q = (np.floor(a / ulp + np.float32(0.5)) * ulp).astype(np.float32)
    q = np.minimum(q, np.float32(448.0))
    q = np.where(a == 0.0, np.float32(0.0), q)
    return np.where(x < 0, -q, q).astype(np.float32)


def mxfp8_rtn(x):
    x = f32(x)
    xg = x.reshape(-1, MX_GROUP)
    s = e8m0_scale(np.max(np.abs(xg), axis=1, keepdims=True), 448.0)
    return (e4m3(xg / s) * s).astype(np.float32).reshape(x.shape)


def block_hadamard(x, g=MX_GROUP):
    """Normalized FWHT per contiguous g-group — the same butterfly order
    as `quant::hadamard::fwht`, so results are bit-identical in f32."""
    x = f32(x)
    y = x.reshape(-1, g).copy()
    h = 1
    while h < g:
        yv = y.reshape(-1, g // (2 * h), 2, h)
        a = yv[:, :, 0, :].copy()
        b = yv[:, :, 1, :].copy()
        yv[:, :, 0, :] = a + b
        yv[:, :, 1, :] = a - b
        h *= 2
    norm = np.float32(1.0) / np.sqrt(np.float32(g))
    return (y * norm).astype(np.float32).reshape(x.shape)


def quant_matmul(x, w, method):
    """y = x·wᵀ under the TrainMethod forward precision (f64 accumulate,
    f32 result — the rust side accumulates in f32; the golden tolerance
    absorbs the sub-ulp difference)."""
    x = f32(x)
    w = f32(w)
    if method == "f32":
        xq, wq = x, w
    elif method == "mxfp8":
        xq, wq = mxfp8_rtn(x), mxfp8_rtn(w)
    elif method == "quartet":
        xq, _ = quest_quantize(block_hadamard(x))
        wq, _ = quest_quantize(block_hadamard(w))
    elif method == "rtn":
        xq, wq = mxfp4_rtn(x), mxfp4_rtn(w)
    else:
        raise ValueError(f"unknown method {method!r}")
    return (xq.astype(np.float64) @ wq.astype(np.float64).T).astype(np.float32)


def rmsnorm(x, g):
    """y = g ⊙ x · rsqrt(mean(x², f64) + 1e-6), per row."""
    x = f32(x)
    ms = np.sum(x.astype(np.float64) ** 2, axis=1, keepdims=True) / x.shape[1]
    inv = (1.0 / np.sqrt(ms + RMS_EPS)).astype(np.float32)
    return (f32(g)[None, :] * x * inv).astype(np.float32)


def rope_rotate(x, n_heads, positions):
    """Rotary rotation of q/k rows `[rows, n_heads·hd]` at `positions`."""
    x = f32(x).copy()
    rows, d = x.shape
    hd = d // n_heads
    half = hd // 2
    i = np.arange(half, dtype=np.float32)
    freqs = np.power(ROPE_THETA, (-(2.0 * i) / np.float32(hd)).astype(np.float32))
    ang = (f32(positions)[:, None] * freqs[None, :]).astype(np.float32)
    c = np.cos(ang).astype(np.float32)
    s = np.sin(ang).astype(np.float32)
    xv = x.reshape(rows, n_heads, half, 2)
    a = xv[:, :, :, 0].copy()
    b = xv[:, :, :, 1].copy()
    xv[:, :, :, 0] = a * c[:, None, :] - b * s[:, None, :]
    xv[:, :, :, 1] = a * s[:, None, :] + b * c[:, None, :]
    return x


def silu(x):
    x = f32(x)
    sg = (np.float32(1.0) / (np.float32(1.0) + np.exp(-x))).astype(np.float32)
    return (x * sg).astype(np.float32)


def causal_attention(q, k, v, n_heads):
    """Per-head causal attention over `[s, d]` rows (training layout,
    pos0 = 0): f64 softmax normalizer, f32 probs, key-order context
    accumulation — the `Backend::attention_causal` twin."""
    s, d = q.shape
    hd = d // n_heads
    scale = np.float32(1.0 / np.sqrt(np.float32(hd)))
    ctx = np.zeros((s, d), dtype=np.float32)
    for h in range(n_heads):
        qh = q[:, h * hd:(h + 1) * hd]
        kh = k[:, h * hd:(h + 1) * hd]
        vh = v[:, h * hd:(h + 1) * hd]
        for i in range(s):
            lim = i + 1
            scores = ((qh[i].astype(np.float64) @ kh[:lim].astype(np.float64).T)
                      .astype(np.float32) * scale).astype(np.float32)
            m = np.max(scores)
            e = np.exp((scores - m).astype(np.float64))
            p = (e / np.sum(e)).astype(np.float32)
            ctx[i, h * hd:(h + 1) * hd] = (
                p.astype(np.float64) @ vh[:lim].astype(np.float64)
            ).astype(np.float32)
    return ctx


class Block:
    def __init__(self, attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down):
        self.attn_norm = f32(attn_norm)
        self.wq, self.wk, self.wv, self.wo = map(f32, (wq, wk, wv, wo))
        self.mlp_norm = f32(mlp_norm)
        self.w_gate, self.w_up, self.w_down = map(f32, (w_gate, w_up, w_down))


def transformer_logits(tok_emb, blocks, final_norm, tokens, n_heads, method):
    """Logits `[s, vocab]` of one sequence — the TransformerLm::logits
    twin (batch rows are independent, so one sequence at a time is
    general)."""
    tok_emb = f32(tok_emb)
    tokens = np.asarray(tokens, dtype=np.int64)
    s = len(tokens)
    x = tok_emb[tokens].copy()
    positions = np.arange(s, dtype=np.float32)
    for blk in blocks:
        a = rmsnorm(x, blk.attn_norm)
        q = quant_matmul(a, blk.wq, method)
        k = quant_matmul(a, blk.wk, method)
        v = quant_matmul(a, blk.wv, method)
        q = rope_rotate(q, n_heads, positions)
        k = rope_rotate(k, n_heads, positions)
        ctx = causal_attention(q, k, v, n_heads)
        x = (x + quant_matmul(ctx, blk.wo, method)).astype(np.float32)
        m = rmsnorm(x, blk.mlp_norm)
        gate = quant_matmul(m, blk.w_gate, method)
        up = quant_matmul(m, blk.w_up, method)
        hsw = (silu(gate) * up).astype(np.float32)
        x = (x + quant_matmul(hsw, blk.w_down, method)).astype(np.float32)
    hn = rmsnorm(x, f32(final_norm))
    # tied vocab head: the shared embedding matrix quantized on the way
    # into the GEMM, same method axis as every other linear
    return quant_matmul(hn, tok_emb, method)
