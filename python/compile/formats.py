"""Numeric-format substrate (L2, build-time only).

Bit-accurate simulations of the micro-scaling formats the paper trains in:

* **MXFP4** — E2M1 element values ``{0, .5, 1, 1.5, 2, 3, 4, 6}`` (signed)
  sharing one **E8M0** power-of-two scale per 1-D group of 32 elements
  (OCP MX spec v1.0, adopted by Blackwell tcgen05.mma).
* **MXFP8 / E4M3** — the paper's "lossless" baseline precision.
* **INT4** — symmetric integer grid for the LSS / LUQ-INT4 baselines.

All functions are quantize-*dequantize* ("fake quant"): they return f32
tensors whose values lie exactly on the target grid, i.e. exactly the
values a Blackwell tensor core would consume. The rust substrate
(`rust/src/quant`) implements the same formats with real nibble packing;
`python/tests/test_formats.py` and `rust quant::tests` pin both to the
same reference vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Grids
# ---------------------------------------------------------------------------

#: Non-negative magnitudes representable by FP4 E2M1 (1 sign, 2 exp, 1 mant).
E2M1_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
E2M1_MAX = 6.0

#: Group size shared by MXFP4 and MXFP8 (OCP MX spec: 1x32 blocks).
MX_GROUP = 32

#: E8M0 scale exponent range (bias 127, value 0xFF = NaN per spec).
E8M0_MIN_EXP = -98  # spec says -127, but XLA CPU flushes f32 subnormals to
# zero (FTZ) — exp2(-126) already rounds into the flushed range, turning 0/s
# into 0/0=NaN on all-zero groups. 2^-98 ≈ 3e-30 is far below any gradient
# magnitude that matters, so clamping the shared-scale exponent here is
# numerically free while keeping the scale a normal f32.
E8M0_MAX_EXP = 127

E4M3_MAX = 448.0
INT4_MAX = 7.0  # symmetric [-7, 7]


def _round_half_away(x):
    """round-to-nearest, ties away from zero (matches the rust substrate)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


# ---------------------------------------------------------------------------
# E2M1 element rounding
# ---------------------------------------------------------------------------

def e2m1_rtn(x):
    """Round values (already divided by their group scale) to the E2M1 grid,
    round-to-nearest with ties away from zero, clamping to ±6."""
    a = jnp.abs(x)
    # Spacing of the E2M1 grid is 0.5 below 2, 1.0 in [2,4), 2.0 in [4,6].
    step = jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))
    q = _round_half_away(a / step) * step
    q = jnp.minimum(q, E2M1_MAX)
    return jnp.sign(x) * q


def e2m1_sr(x, u):
    """Stochastic rounding to the E2M1 grid.

    ``u`` is uniform(0,1) noise of the same shape. Rounds to one of the two
    neighbouring grid points with probability proportional to proximity,
    which makes ``E[e2m1_sr(x,U)] == clip(x, -6, 6)`` exactly — the property
    Quartet's backward pass relies on. Inputs must satisfy |x| <= 6 for the
    estimator to be unbiased (the 3/4 pre-scaling in Algorithm 1 guarantees
    this).
    """
    a = jnp.clip(jnp.abs(x), 0.0, E2M1_MAX)
    step = jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))
    lo = jnp.floor(a / step) * step
    # Step size of the interval we actually landed in (handles the 2.0 / 4.0
    # boundaries where spacing changes: interval is [lo, lo+step_of_lo)).
    step_lo = jnp.where(lo < 2.0, 0.5, jnp.where(lo < 4.0, 1.0, 2.0))
    hi = jnp.minimum(lo + step_lo, E2M1_MAX)
    frac = jnp.where(hi > lo, (a - lo) / (hi - lo), 0.0)
    q = jnp.where(u < frac, hi, lo)
    return jnp.sign(x) * q


# ---------------------------------------------------------------------------
# E8M0 group scales
# ---------------------------------------------------------------------------

def e8m0_scale(group_absmax, target_max=E2M1_MAX):
    """Power-of-two scale s = 2^ceil(log2(absmax/target_max)).

    Guarantees absmax/s <= target_max (no clipping), matching the OCP MX
    "shared scale computed from the largest magnitude" rule with ceil
    rounding, and clamps the exponent to the E8M0 range.
    """
    safe = jnp.maximum(group_absmax, 2.0 ** (E8M0_MIN_EXP))
    exp = jnp.ceil(jnp.log2(safe / target_max))
    exp = jnp.clip(exp, E8M0_MIN_EXP, E8M0_MAX_EXP)
    return jnp.exp2(exp)


def _group_reshape(x, group=MX_GROUP):
    """[..., d] -> [..., d/group, group]; d must divide by group."""
    d = x.shape[-1]
    if d % group != 0:
        raise ValueError(f"last dim {d} not divisible by MX group {group}")
    return x.reshape(*x.shape[:-1], d // group, group)


def _group_unreshape(xg):
    return xg.reshape(*xg.shape[:-2], xg.shape[-2] * xg.shape[-1])


# ---------------------------------------------------------------------------
# MXFP4 quantize-dequantize
# ---------------------------------------------------------------------------

def mxfp4_rtn(x, group=MX_GROUP):
    """AbsMax MXFP4 with deterministic round-to-nearest (per 1x32 group)."""
    xg = _group_reshape(x, group)
    s = e8m0_scale(jnp.max(jnp.abs(xg), axis=-1, keepdims=True))
    q = e2m1_rtn(xg / s) * s
    return _group_unreshape(q)


def mxfp4_sr(x, u, group=MX_GROUP, prescale=0.75):
    """Unbiased stochastic MXFP4: Algorithm 1's ``SR(3/4 · x)``.

    The e8m0 absmax scale maps the group into [-6, 6]; the extra 3/4
    pre-scale keeps every value strictly inside the grid so stochastic
    rounding never clips, making the quantizer exactly unbiased up to the
    known 4/3 factor, which the caller compensates (16/9 on a product of
    two such tensors).

    Returns values on the grid *including* the 3/4 shrinkage — i.e. this is
    the tensor the GEMM consumes; multiply the GEMM output by (1/prescale)^2.
    """
    xg = _group_reshape(x, group)
    ug = _group_reshape(u, group)
    s = e8m0_scale(jnp.max(jnp.abs(xg), axis=-1, keepdims=True))
    q = e2m1_sr(prescale * xg / s, ug) * s
    return _group_unreshape(q)


# ---------------------------------------------------------------------------
# QuEST projection (forward-pass quantizer of Quartet)
# ---------------------------------------------------------------------------

# MSE-optimal clip multiplier for RTN-E2M1 on unit Gaussian data, i.e. the
# alpha minimising E[(X - rtn(clip(X, a)) * ...)^2]. Computed once
# numerically (seeded) — see _fit_quest_alpha below; value pinned so the
# artifact stream is deterministic and the rust substrate can share it.
QUEST_ALPHA_E2M1 = 2.925


def _fit_quest_alpha(n=1 << 22, seed=0):
    """Numerically refit QUEST_ALPHA_E2M1 (used by tests, not at trace time)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    alphas = np.linspace(1.5, 4.5, 121)
    best, best_mse = None, np.inf
    for a in alphas:
        s = a / E2M1_MAX
        q = np.asarray(e2m1_rtn(jnp.asarray(x / s))) * s
        mse = float(np.mean((x - q) ** 2))
        if mse < best_mse:
            best, best_mse = a, mse
    return float(best)


def quest_quantize(x, group=MX_GROUP):
    """QuEST projection to MXFP4 (Panferov et al., 2025, adapted to E2M1).

    The caller applies the Hadamard transform first (which normalises the
    per-group distribution towards Gaussian); here we pick the RMSE-optimal
    clip ``alpha * rms(group)`` instead of absmax, snap it to the E8M0
    power-of-two grid, RTN-quantize, and emit the *trust mask* — 1 where the
    value was representable (|x| <= clip), 0 where it was clipped — used by
    the backward pass as the clipping-aware STE.

    Returns ``(q, mask)`` with q dequantized f32 on the MXFP4 grid.
    """
    xg = _group_reshape(x, group)
    rms = jnp.sqrt(jnp.mean(xg * xg, axis=-1, keepdims=True) + 1e-20)
    clip = QUEST_ALPHA_E2M1 * rms
    # The RMSE-optimal scale clip/6 rarely lands on the E8M0 power-of-two
    # grid; evaluate both neighbouring binades against the *actual* group
    # and keep the lower-MSE one ("more precise MSE fitting", QuEST §3).
    e = jnp.log2(jnp.maximum(clip / E2M1_MAX, 2.0 ** E8M0_MIN_EXP))
    s_lo = jnp.exp2(jnp.clip(jnp.floor(e), E8M0_MIN_EXP, E8M0_MAX_EXP))
    s_hi = jnp.exp2(jnp.clip(jnp.ceil(e), E8M0_MIN_EXP, E8M0_MAX_EXP))
    q_lo = e2m1_rtn(xg / s_lo) * s_lo
    q_hi = e2m1_rtn(xg / s_hi) * s_hi
    mse_lo = jnp.mean((q_lo - xg) ** 2, axis=-1, keepdims=True)
    mse_hi = jnp.mean((q_hi - xg) ** 2, axis=-1, keepdims=True)
    use_lo = mse_lo <= mse_hi
    q = jnp.where(use_lo, q_lo, q_hi)
    s = jnp.where(use_lo, s_lo, s_hi)
    mask = (jnp.abs(xg) <= s * E2M1_MAX).astype(x.dtype)
    return _group_unreshape(q), _group_unreshape(mask)


# ---------------------------------------------------------------------------
# Generic small-float rounding (FP8 baseline)
# ---------------------------------------------------------------------------

def round_to_float(x, ebits, mbits, max_val):
    """Round f32 to a small float format (nearest), flush subnormals-ish.

    Used for E4M3 (ebits=4, mbits=3, max=448) and E5M2. Implements
    round-to-nearest on the mantissa at the value's own binade, clamping to
    ±max_val; magnitudes below the smallest normal round on the subnormal
    grid of the smallest binade.
    """
    bias = 2 ** (ebits - 1) - 1
    min_exp = 1 - bias  # smallest normal exponent
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38)))
    e = jnp.maximum(e, float(min_exp))
    ulp = jnp.exp2(e - mbits)
    q = _round_half_away(a / ulp) * ulp
    q = jnp.minimum(q, max_val)
    q = jnp.where(a == 0.0, 0.0, q)
    return jnp.sign(x) * q


def e4m3(x):
    return round_to_float(x, 4, 3, E4M3_MAX)


def mxfp8_rtn(x, group=MX_GROUP):
    """MXFP8: E4M3 elements + shared E8M0 group scale — the FP8 baseline."""
    xg = _group_reshape(x, group)
    s = e8m0_scale(jnp.max(jnp.abs(xg), axis=-1, keepdims=True), target_max=E4M3_MAX)
    q = e4m3(xg / s) * s
    return _group_unreshape(q)


# ---------------------------------------------------------------------------
# INT4 (LSS / LUQ baselines)
# ---------------------------------------------------------------------------

def int4_rtn(x, group=MX_GROUP):
    xg = _group_reshape(x, group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-20) / INT4_MAX
    q = jnp.clip(_round_half_away(xg / s), -INT4_MAX, INT4_MAX) * s
    return _group_unreshape(q)


def int4_sr(x, u, group=MX_GROUP):
    xg = _group_reshape(x, group)
    ug = _group_reshape(u, group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-20) / INT4_MAX
    y = jnp.clip(xg / s, -INT4_MAX, INT4_MAX)
    lo = jnp.floor(y)
    q = jnp.where(ug < (y - lo), lo + 1.0, lo) * s
    return _group_unreshape(q)


# ---------------------------------------------------------------------------
# LUQ: logarithmic unbiased quantization (Chmiel et al., 2023)
# ---------------------------------------------------------------------------

def luq_fp4(x, u, group=MX_GROUP):
    """LUQ mapped onto an FP4-style log grid.

    Per group: threshold t = absmax / 2^(levels-1); magnitudes below t are
    *stochastically pruned* (to 0 or t, unbiased "stochastic underflow");
    the rest are stochastically rounded between neighbouring powers of two
    (unbiased in expectation on the log grid).
    """
    levels = 7  # power-of-two levels between t and absmax (4-bit-ish)
    xg = _group_reshape(x, group)
    ug = _group_reshape(u, group)
    amax = jnp.maximum(jnp.max(jnp.abs(xg), axis=-1, keepdims=True), 1e-20)
    t = amax / (2.0 ** (levels - 1))
    a = jnp.abs(xg)
    # stochastic underflow below t
    under = a < t
    a_under = jnp.where(ug * t < a, t, 0.0)
    # unbiased SR between log2 neighbours at/above t
    la = jnp.log2(jnp.maximum(a, t) / t)
    lo = jnp.floor(la)
    frac = (2.0 ** la - 2.0 ** lo) / (2.0 ** lo)  # position within [2^lo, 2^(lo+1)]
    a_log = jnp.where(ug < frac, 2.0 ** (lo + 1.0), 2.0 ** lo) * t
    q = jnp.where(under, a_under, a_log)
    return _group_unreshape(jnp.sign(xg) * q)


def luq_int4(x, u, group=MX_GROUP):
    """LUQ's INT4 variant: stochastic underflow + SR on the integer grid."""
    return int4_sr(x, u, group)


# ---------------------------------------------------------------------------
# Jetfire: 2-D block quantization (Xi et al., 2024), ported to FP4
# ---------------------------------------------------------------------------

def jetfire_fp4(x, block=32):
    """Per-(32x32)-block absmax RTN to E2M1. x must be 2-D [rows, cols]."""
    r, c = x.shape
    if r % block or c % block:
        raise ValueError(f"jetfire block {block} must divide {x.shape}")
    xb = x.reshape(r // block, block, c // block, block)
    amax = jnp.max(jnp.abs(xb), axis=(1, 3), keepdims=True)
    s = jnp.maximum(amax, 1e-20) / E2M1_MAX
    q = e2m1_rtn(xb / s) * s
    return q.reshape(r, c)


# ---------------------------------------------------------------------------
# HALO-style: Hadamard + per-tensor scale RTN FP4
# ---------------------------------------------------------------------------

def halo_fp4(x):
    """HALO-2-like quantizer: (block) Hadamard already applied by the
    caller; per-*tensor* absmax scale + RTN E2M1 (coarser than MXFP4's
    group scales — the source of HALO's FP4 instability in Table 3)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20)
    s = amax / E2M1_MAX
    return e2m1_rtn(x / s) * s
