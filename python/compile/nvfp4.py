"""Pure-numpy NVFP4 twin of the rust reference quantizer (L2, build-time).

Mirrors ``rust/src/quant/format.rs::quantize_ref`` for the NVFP4
descriptor — 16-element groups, E2M1 elements, fractional E4M3 group
scales, and a second-level power-of-two tensor scale — operation for
operation in float32, so the two substrates agree bit-for-bit on codes
and scales (up to the measure-zero log2-rounding windows noted below).

Deliberately **jax-free**: unlike ``compile.formats`` this module runs in
a bare numpy environment, because its only job is to regenerate the
cross-language golden vectors consumed by
``rust prop_quant::nvfp4_golden_vectors_match_python``.

Usage: ``python -m compile.nvfp4 [out.json]`` (default writes
``rust/tests/data/nvfp4_vectors.json``).
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

#: Non-negative E2M1 magnitudes (shared with compile.formats, restated so
#: this module stays import-light).
E2M1_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
E2M1_MAX = np.float32(6.0)

E4M3_MAX = np.float32(448.0)
#: Smallest positive E4M3 value (subnormal step 2^-9) — the floor for
#: group scales so a zero group still has an invertible scale.
E4M3_MIN_POS = np.float32(1.0 / 512.0)

#: E8M0 exponent clamp shared with the MX scale rule (see formats.py for
#: why the floor is -98 and not the spec's -127).
E8M0_MIN_EXP = -98
E8M0_MAX_EXP = 127

#: NVFP4 group size (the MX formats use 32).
GROUP = 16

_HALF = np.float32(0.5)
_ONE = np.float32(1.0)
_TWO = np.float32(2.0)


def _floor_log2_f32(a):
    """Exact floor(log2(a)) for a > 0 via frexp (no libm rounding).

    The rust side computes ``a.log2().floor()``; a faithfully-rounded f32
    log2 can only disagree with the exact answer when ``a`` sits within
    ~1 output ulp of a power of two, and at those points both ulp choices
    ceil to the same next-binade scale — so frexp is the safer twin.
    """
    _, e = np.frexp(np.float32(a))
    return int(e) - 1


def e2m1_rtn(x):
    """Round float32 values to the E2M1 grid — nearest, ties away from
    zero, clamped to ±6. Same arithmetic as ``rust e2m1::e2m1_rtn`` (the
    grid steps are powers of two, so every intermediate is exact)."""
    x = np.asarray(x, dtype=np.float32)
    a = np.abs(x)
    step = np.where(a < 2.0, _HALF, np.where(a < 4.0, _ONE, _TWO)).astype(np.float32)
    q = (np.floor(a / step + _HALF) * step).astype(np.float32)
    q = np.minimum(q, E2M1_MAX)
    return np.where(np.signbit(x), -q, q).astype(np.float32)


def e4m3_ceil(x):
    """Round a non-negative float32 UP to the next E4M3 magnitude,
    clamping to 448 (identity on the grid) — ``rust fp8::e4m3_ceil``."""
    x = np.float32(x)
    if x <= 0.0:
        return np.float32(0.0)
    a = np.float32(min(float(x), float(E4M3_MAX)))
    e = max(_floor_log2_f32(a), -6)
    ulp = np.float32(2.0 ** (e - 3))
    return np.float32(min(float(np.ceil(a / ulp) * ulp), float(E4M3_MAX)))


def tensor_scale(global_absmax):
    """Second-level power-of-two scale: 2^ceil(log2(absmax / (448·6))),
    exponent clamped to the E8M0 range — ``GroupFormat::tensor_scale``."""
    safe = np.float32(max(float(global_absmax), 2.0 ** E8M0_MIN_EXP))
    r = safe / np.float32(E4M3_MAX * E2M1_MAX)
    exp = int(np.ceil(np.log2(r)))
    exp = min(max(exp, E8M0_MIN_EXP), E8M0_MAX_EXP)
    return np.float32(2.0 ** exp)


def nvfp4_rtn(x):
    """NVFP4 quantize-dequantize of a [rows, cols] float32 tensor.

    Returns ``(dq, group_scales, s_t)``: the dequantized tensor, the
    *decoded* per-group E4M3 scales [rows, cols/16] (tensor scale not
    included), and the tensor scale — exactly the triple the rust
    ``GroupTensor`` stores.
    """
    x = np.asarray(x, dtype=np.float32)
    rows, cols = x.shape
    if cols % GROUP:
        raise ValueError(f"cols {cols} not divisible by the NVFP4 group {GROUP}")
    s_t = tensor_scale(np.max(np.abs(x)) if x.size else 0.0)
    xg = x.reshape(rows, cols // GROUP, GROUP)
    dq = np.zeros_like(xg)
    scales = np.zeros((rows, cols // GROUP), dtype=np.float32)
    for r in range(rows):
        for g in range(cols // GROUP):
            grp = xg[r, g]
            amax = np.float32(np.max(np.abs(grp)))
            # encode_scale: ceil'd fractional scale, floored so zero
            # groups stay invertible
            target = amax / (s_t * E2M1_MAX)
            s = np.float32(max(float(e4m3_ceil(target)), float(E4M3_MIN_POS)))
            scales[r, g] = s
            # rust multiplies by the f32 reciprocal, not divides — the
            # two differ in the last ulp, which can flip an RTN tie
            inv = _ONE / (s * s_t)
            dq[r, g] = e2m1_rtn(grp * inv) * (s * s_t)
    return dq.reshape(rows, cols), scales, s_t


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
        "nvfp4_vectors.json")
    rng = np.random.default_rng(20250711)
    cases = []
    for rows, cols, scale in [(1, 32, 1.0), (2, 64, 0.01), (1, 96, 100.0),
                              (3, 32, 1e-6), (2, 160, 1.0)]:
        x = (rng.standard_normal((rows, cols)) * scale).astype(np.float32)
        # exercise exact zeros, a whole-group zero run (the E4M3_MIN_POS
        # floor), and a two-level outlier that drags the tensor scale
        x[0, 0] = 0.0
        if cols >= 64:
            x[0, 16:32] = 0.0
            x[rows - 1, 33] = 24.0 * scale
        dq, scales, s_t = nvfp4_rtn(x)
        cases.append({
            "rows": rows,
            "cols": cols,
            "x": [float(v) for v in x.reshape(-1)],
            "tensor_scale": float(s_t),
            "group_scales": [float(v) for v in scales.reshape(-1)],
            "nvfp4_rtn": [float(v) for v in dq.reshape(-1)],
        })
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"seed": 20250711, "cases": cases}, f)
    print(f"wrote {len(cases)} cases to {out}")


if __name__ == "__main__":
    main()
