"""AOT lowering: JAX entrypoints → HLO *text* + manifest.json (build time).

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` —
is the interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 rust crate links) rejects; the text parser reassigns ids and
round-trips cleanly.

One artifact directory per (model config, method):

    artifacts/<cfg>-<method>/
        manifest.json          # config, flat param table, entrypoint sigs
        train_step.hlo.txt     # 1 optimizer step
        train_segment.hlo.txt  # K steps under one PJRT call (fori_loop)
        eval_loss.hlo.txt      # validation loss on one batch
        forward.hlo.txt        # prefill logits (serving)

Artifact *sets* group what the rust experiments need:
  default  — quickstart (n80k-quartet, n80k-fp8, n80k-bf16) + n20k smokes
             + the pallas-lowered variant (kernel-composition proof)
  table3   — all Table 3 methods at nano scale
  sweep    — the scaling-law model-size grid (quartet/fp8/bf16 + ablations)
  serve    — forward-only artifacts at batch 1..128 for Fig 6
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .formats import QUEST_ALPHA_E2M1
from .model import (
    ModelConfig,
    eval_loss,
    forward,
    param_shapes,
    train_segment,
    train_step,
)

# ---------------------------------------------------------------------------
# model-size registry (nano series; see EXPERIMENTS.md for the mapping to the
# paper's 30M–200M grid — the scaling-law machinery is scale-free)
# ---------------------------------------------------------------------------

SIZES = {
    #        d_model layers heads d_ff   ~non-emb params
    "n20k": (32, 2, 2, 64),  #      20.6k
    "n40k": (32, 4, 2, 64),  #      41.2k
    "n80k": (64, 2, 2, 128),  #     82.2k
    "n160k": (64, 4, 2, 128),  #   164.2k
    "n330k": (96, 4, 3, 192),  #   369.8k
    "n1m": (128, 6, 4, 256),  #    984.6k
    "n8m": (320, 8, 5, 640),  #    8.20M  ("large" run, Fig 3c)
}

VOCAB = 512
SEQ_LEN = 64
BATCH = 8
SEGMENT_K = 8


def base_lr(n_nonemb: int) -> float:
    """Paper A.1 scales LR inverse-proportionally to non-embedding params
    from a tuned small-model anchor; we anchor 2e-3 at 20k params with
    sqrt scaling (tuned on the unquantized nano baseline, then reused for
    every quantization scheme — same protocol as the paper)."""
    return float(2e-3 * np.sqrt(20_480.0 / n_nonemb))


def make_config(size: str, method: str, batch: int = BATCH,
                seq_len: int = SEQ_LEN, vocab: int = VOCAB) -> ModelConfig:
    d, layers, heads, ff = SIZES[size]
    cfg = ModelConfig(
        name=size, d_model=d, n_layers=layers, n_heads=heads, d_ff=ff,
        vocab=vocab, seq_len=seq_len, batch=batch, method=method,
    )
    return dataclasses.replace(cfg, lr=base_lr(cfg.non_embedding_params()))


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _flat_state_specs(cfg: ModelConfig):
    """Flattened (params ‖ m ‖ v) input/output table, sorted-name order."""
    shapes = param_shapes(cfg)
    out = []
    for group in ("param", "m", "v"):
        for name, shape in shapes.items():
            out.append({"name": f"{group}:{name}", **_spec(shape)})
    return out


def _state_structs(cfg: ModelConfig):
    shapes = param_shapes(cfg)
    one = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes.values()]
    return one * 3  # params, m, v


def _pack(cfg: ModelConfig, flat):
    """flat list (params‖m‖v) → three name→array dicts."""
    names = list(param_shapes(cfg).keys())
    n = len(names)
    params = dict(zip(names, flat[:n]))
    m = dict(zip(names, flat[n : 2 * n]))
    v = dict(zip(names, flat[2 * n :]))
    return params, m, v


def _unpack(cfg: ModelConfig, params, m, v):
    names = list(param_shapes(cfg).keys())
    return [params[k] for k in names] + [m[k] for k in names] + [v[k] for k in names]


def lower_artifact(cfg: ModelConfig, out_dir: str, segment_k: int = SEGMENT_K,
                   entrypoints=("train_step", "train_segment", "eval_loss", "forward"),
                   forward_batch: int | None = None, quiet: bool = False,
                   suffix: str = ""):
    """Lower all entrypoints for one (config, method) and write the manifest."""
    name = f"{cfg.name}-{cfg.method}{suffix}"
    adir = os.path.join(out_dir, name)
    os.makedirs(adir, exist_ok=True)

    B, S, K = cfg.batch, cfg.seq_len, segment_k
    fb = forward_batch or B
    i32 = jnp.int32
    scalar_i = jax.ShapeDtypeStruct((), i32)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    state = _state_structs(cfg)

    def ts_fn(step, seed, lr, total, tokens, *flat):
        p, m, v = _pack(cfg, flat)
        loss, p, m, v = train_step(step, seed, lr, total, tokens, p, m, v, cfg)
        return (loss, *_unpack(cfg, p, m, v))

    def seg_fn(step, seed, lr, total, tokens, *flat):
        p, m, v = _pack(cfg, flat)
        mean_l, last_l, p, m, v = train_segment(
            step, seed, lr, total, tokens, p, m, v, cfg
        )
        return (mean_l, last_l, *_unpack(cfg, p, m, v))

    def eval_fn(tokens, *flat_params):
        names = list(param_shapes(cfg).keys())
        return (eval_loss(tokens, dict(zip(names, flat_params)), cfg),)

    def fwd_fn(tokens, *flat_params):
        names = list(param_shapes(cfg).keys())
        return (forward(tokens, dict(zip(names, flat_params)), cfg),)

    manifest_eps = {}

    def lower(fname, fn, in_specs, in_names, out_names):
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(adir, f"{fname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_eps[fname] = {
            "file": f"{fname}.hlo.txt",
            "inputs": in_names,
            "outputs": out_names,
        }
        if not quiet:
            print(f"  {name}/{fname}: {len(text)/1e6:.2f} MB HLO text")

    flat_specs = _flat_state_specs(cfg)
    params_only = [s for s in flat_specs if s["name"].startswith("param:")]
    scalars = [
        {"name": "step", **_spec((), "i32")},
        {"name": "seed", **_spec((), "i32")},
        {"name": "lr", **_spec((), "f32")},
        {"name": "total_steps", **_spec((), "f32")},
    ]

    if "train_step" in entrypoints:
        lower(
            "train_step", ts_fn,
            [scalar_i, scalar_i, scalar_f, scalar_f,
             jax.ShapeDtypeStruct((B, S + 1), i32), *state],
            scalars + [{"name": "tokens", **_spec((B, S + 1), "i32")}] + flat_specs,
            [{"name": "loss", **_spec(())}] + flat_specs,
        )
    if "train_segment" in entrypoints:
        lower(
            "train_segment", seg_fn,
            [scalar_i, scalar_i, scalar_f, scalar_f,
             jax.ShapeDtypeStruct((K, B, S + 1), i32), *state],
            scalars + [{"name": "tokens", **_spec((K, B, S + 1), "i32")}] + flat_specs,
            [{"name": "mean_loss", **_spec(())}, {"name": "last_loss", **_spec(())}]
            + flat_specs,
        )
    if "eval_loss" in entrypoints:
        lower(
            "eval_loss", eval_fn,
            [jax.ShapeDtypeStruct((B, S + 1), i32), *_state_structs(cfg)[: len(params_only)]],
            [{"name": "tokens", **_spec((B, S + 1), "i32")}] + params_only,
            [{"name": "loss", **_spec(())}],
        )
    if "forward" in entrypoints:
        lower(
            "forward", fwd_fn,
            [jax.ShapeDtypeStruct((fb, S), i32), *_state_structs(cfg)[: len(params_only)]],
            [{"name": "tokens", **_spec((fb, S), "i32")}] + params_only,
            [{"name": "logits", **_spec((fb, S, cfg.vocab))}],
        )

    manifest = {
        "version": 1,
        "name": name,
        "config": dataclasses.asdict(cfg),
        "non_embedding_params": cfg.non_embedding_params(),
        "embedding_params": cfg.embedding_params(),
        "segment_k": K,
        "quest_alpha": QUEST_ALPHA_E2M1,
        "params": [
            {"name": n, **_spec(s)} for n, s in param_shapes(cfg).items()
        ],
        "entrypoints": manifest_eps,
    }
    with open(os.path.join(adir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return adir


# ---------------------------------------------------------------------------
# artifact sets
# ---------------------------------------------------------------------------

TABLE3_METHODS = ["quartet", "luq_int4", "luq_fp4", "jetfire_fp4", "halo_fp4",
                  "lss_int4", "fp8", "bf16"]
ABLATION_METHODS = ["quest_fwd", "rtn_fwd", "sr_fwd", "sr_bwd", "rtn_bwd",
                    "rtn_pma_bwd", "rtn", "sr"]


def build_set(which: str, out_dir: str, quiet: bool = False):
    jobs = []  # (cfg, kwargs)
    if which in ("default", "all"):
        jobs += [(make_config("n80k", m), {}) for m in ("quartet", "fp8", "bf16")]
        jobs += [(make_config("n20k", "quartet"), {})]
        # kernel-composition proof: pallas-lowered train_step only
        jobs += [(make_config("n20k", "quartet_pallas"),
                  {"entrypoints": ("train_step",)})]
    if which in ("table3", "all"):
        jobs += [
            (make_config("n20k", m), {})
            for m in TABLE3_METHODS if m != "bf16"  # bf16/fp8 shared with sweep
        ] + [(make_config("n20k", "bf16"), {})]
    if which in ("sweep", "all"):
        for size in ("n20k", "n40k", "n80k", "n160k"):
            for m in ("quartet", "fp8", "bf16"):
                jobs.append((make_config(size, m), {}))
        for m in ABLATION_METHODS:
            jobs.append((make_config("n20k", m), {}))
    if which in ("dynamics", "all"):
        jobs += [(make_config("n1m", m), {}) for m in ("quartet", "fp8")]
    if which in ("serve", "all"):
        for b in (1, 2, 4, 8, 16, 32, 64, 128):
            jobs.append(
                (make_config("n330k", "quartet", batch=b),
                 {"entrypoints": ("forward",), "forward_batch": b,
                  "suffix": f"-b{b}"})
            )
            jobs.append(
                (make_config("n330k", "fp8", batch=b),
                 {"entrypoints": ("forward",), "forward_batch": b,
                  "suffix": f"-b{b}"})
            )
    if not jobs:
        raise SystemExit(f"unknown artifact set {which!r}")

    seen = set()
    for cfg, kw in jobs:
        key = (cfg.name, cfg.method, cfg.batch, kw.get("forward_batch"))
        if key in seen:
            continue
        seen.add(key)
        lower_artifact(cfg, out_dir, quiet=quiet, **kw)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", dest="which", default=None,
                    help="default|table3|sweep|dynamics|serve|all")
    ap.add_argument("--size", default=None, help="single size, e.g. n80k")
    ap.add_argument("--method", default="quartet")
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--segment-k", type=int, default=SEGMENT_K)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    if args.which:
        build_set(args.which, args.out_dir, quiet=args.quiet)
    elif args.size:
        cfg = make_config(args.size, args.method, batch=args.batch)
        lower_artifact(cfg, args.out_dir, segment_k=args.segment_k, quiet=args.quiet)
    else:
        raise SystemExit("pass --set or --size")


if __name__ == "__main__":
    main()
