"""L2 — Llama-2-style transformer with Quartet quantized linears (build time).

Defines the model forward/backward, the AdamW-with-cosine-schedule update
*inside the graph*, and the entrypoints the rust coordinator loads:

* ``train_step``     — one optimizer step.
* ``train_segment``  — K optimizer steps in one ``lax.fori_loop`` (amortizes
                       the host↔device round trip PJRT tuple outputs force).
* ``forward`` / ``eval_loss`` — inference logits / validation loss.

All linear layers (QKV/O + SwiGLU gate/up/down) go through
``quartet.quant_linear`` with the configured method; embeddings, the tied
LM head, norms and attention internals stay in full precision, matching
the paper's setup (only the three linear-layer GEMMs are low precision).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .quartet import METHODS, Method, quant_linear


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Model + schedule hyper-parameters; all dims multiples of 32 (MX group)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    batch: int
    method: str = "quartet"
    lr: float = 1e-3
    warmup_frac: float = 0.1
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8

    def __post_init__(self):
        for nm, v in (("d_model", self.d_model), ("d_ff", self.d_ff)):
            if v % 32:
                raise ValueError(f"{nm}={v} must be a multiple of 32 (MX group)")
        if (self.batch * self.seq_len) % 32:
            raise ValueError("batch*seq_len must be a multiple of 32 for the dW GEMM")
        if self.d_model % self.n_heads:
            raise ValueError("d_model % n_heads != 0")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def non_embedding_params(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff
        norms = self.n_layers * 2 * self.d_model + self.d_model
        return self.n_layers * per_layer + norms

    def embedding_params(self) -> int:
        return self.vocab * self.d_model


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Name → shape, in the sorted-key order jax flattens dicts with.

    Per-layer weights are *stacked* along a leading L axis and the model
    scans over them (`lax.scan`): layer code appears once in the lowered
    HLO regardless of depth, which keeps XLA-CPU AOT compile time flat in
    n_layers (the §Perf L2 fix — unrolled layers made the 2021-era XLA
    backend spend minutes per artifact)."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    shapes = {
        "tok_emb": (cfg.vocab, d),
        "final_norm": (d,),
        "layers.attn_norm": (L, d),
        "layers.wq": (L, d, d),
        "layers.wk": (L, d, d),
        "layers.wv": (L, d, d),
        "layers.wo": (L, d, d),
        "layers.mlp_norm": (L, d),
        "layers.w_gate": (L, ff, d),
        "layers.w_up": (L, ff, d),
        "layers.w_down": (L, d, ff),
    }
    return dict(sorted(shapes.items()))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic init (numpy RNG, seeded): scaled-normal linears,
    GPT-2-style 1/sqrt(2L) down-scaling on residual-writing projections.
    Stacked-layer tensors draw one normal per element, so every layer gets
    independent weights."""
    rng = np.random.default_rng(seed)
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    resid = 1.0 / np.sqrt(2 * L)

    def scale_for(name: str) -> float:
        leaf = name.split(".")[-1]
        if leaf == "tok_emb":
            return 0.02
        if leaf in ("wq", "wk", "wv"):
            return 1.0 / np.sqrt(d)
        if leaf == "wo":
            return resid / np.sqrt(d)
        if leaf in ("w_gate", "w_up"):
            return 1.0 / np.sqrt(d)
        if leaf == "w_down":
            return resid / np.sqrt(ff)
        return 0.0  # norms handled below

    p = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith("norm"):
            p[name] = jnp.ones(shape, jnp.float32)
        else:
            p[name] = jnp.asarray(
                rng.standard_normal(shape).astype(np.float32) * scale_for(name)
            )
    return p


def _is_linear(name: str) -> bool:
    """Parameters that are quantized linear weights (get weight decay)."""
    leaf = name.rsplit(".", 1)[-1]
    return leaf in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


# ---------------------------------------------------------------------------
# model forward
# ---------------------------------------------------------------------------


def _rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


@functools.lru_cache(maxsize=None)
def _rope_tables(seq_len: int, head_dim: int):
    # numpy outputs (not jnp) so the lru_cache never captures tracers.
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    inv = 1.0 / (10000.0 ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))
    ang = pos * inv[None, :]
    return np.cos(ang), np.sin(ang)


def _rope(x, cos, sin):
    """x: [B, S, H, hd]; rotate (even, odd) pairs."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, :, None, :], sin[None, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def model_forward(params, tokens, cfg: ModelConfig, key):
    """tokens: int32 [B, S] → logits f32 [B, S, vocab]."""
    method = METHODS[cfg.method]
    B, S = tokens.shape
    d = cfg.d_model
    h = params["tok_emb"][tokens]  # [B, S, d]
    cos_np, sin_np = _rope_tables(S, cfg.head_dim)
    cos, sin = jnp.asarray(cos_np), jnp.asarray(sin_np)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    causal = jnp.tril(jnp.ones((S, S), jnp.bool_))

    def layer(h, xs):
        """One transformer block (scanned: lowers once for all layers)."""
        lp, idx = xs
        lk = jax.random.fold_in(key, idx)

        def qlin(x2d, name, slot):
            return quant_linear(x2d, lp[name], jax.random.fold_in(lk, slot), method)

        x2 = _rmsnorm(h, lp["attn_norm"]).reshape(B * S, d)
        q = qlin(x2, "wq", 0)
        k = qlin(x2, "wk", 1)
        v = qlin(x2, "wv", 2)
        q = _rope(q.reshape(B, S, cfg.n_heads, cfg.head_dim), cos, sin)
        k = _rope(k.reshape(B, S, cfg.n_heads, cfg.head_dim), cos, sin)
        v = v.reshape(B, S, cfg.n_heads, cfg.head_dim)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B * S, d)
        o = qlin(o, "wo", 3)
        h = h + o.reshape(B, S, d)

        x2 = _rmsnorm(h, lp["mlp_norm"]).reshape(B * S, d)
        g = qlin(x2, "w_gate", 4)
        u = qlin(x2, "w_up", 5)
        mid = jax.nn.silu(g) * u
        dn = qlin(mid, "w_down", 6)
        h = h + dn.reshape(B, S, d)
        return h, None

    stacked = {
        name.split(".", 1)[1]: params[name]
        for name in params
        if name.startswith("layers.")
    }
    h, _ = jax.lax.scan(layer, h, (stacked, jnp.arange(cfg.n_layers)))

    h = _rmsnorm(h, params["final_norm"])
    # tied LM head in full precision (paper keeps embeddings/head high-prec)
    return h @ params["tok_emb"].T


def loss_fn(params, tokens_in, targets, cfg: ModelConfig, key):
    logits = model_forward(params, tokens_in, cfg, key)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# AdamW + cosine schedule, in-graph
# ---------------------------------------------------------------------------


def lr_at(step, base_lr, total_steps, cfg: ModelConfig):
    """Cosine decay with 10% linear warmup (paper Appendix A.1).

    ``base_lr``/``total_steps`` are *runtime inputs* (traced scalars) so one
    AOT artifact serves every token-budget point of a sweep — the rust
    coordinator picks the schedule per run.
    """
    step_f = jnp.asarray(step, jnp.float32)
    total_f = jnp.asarray(total_steps, jnp.float32)
    warm = jnp.maximum(total_f * cfg.warmup_frac, 1.0)
    warm_lr = base_lr * (step_f + 1.0) / warm
    prog = jnp.clip((step_f - warm) / jnp.maximum(total_f - warm, 1.0), 0.0, 1.0)
    cos_lr = base_lr * 0.5 * (1.0 + jnp.cos(np.pi * prog))
    return jnp.where(step_f < warm, warm_lr, cos_lr)


def adamw_update(params, grads, m, v, step, lr, cfg: ModelConfig):
    t = jnp.asarray(step, jnp.float32) + 1.0
    b1, b2 = cfg.adam_b1, cfg.adam_b2

    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)) + 1e-12
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / gnorm)

    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name] * clip
        nm = b1 * m[name] + (1 - b1) * g
        nv = b2 * v[name] + (1 - b2) * g * g
        mhat = nm / (1 - b1**t)
        vhat = nv / (1 - b2**t)
        upd = mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
        if _is_linear(name):
            upd = upd + cfg.weight_decay * params[name]
        new_p[name] = params[name] - lr * upd
        new_m[name] = nm
        new_v[name] = nv
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# entrypoints (lowered by aot.py)
# ---------------------------------------------------------------------------


def train_step(step, seed, lr, total_steps, tokens, params, m, v, cfg: ModelConfig):
    """One optimizer step. tokens: i32[B, S+1] (positions 0..S-1 are inputs,
    1..S the shifted targets); lr/total_steps are runtime scalars."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    tokens_in, targets = tokens[:, :-1], tokens[:, 1:]
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens_in, targets, cfg, key)
    step_lr = lr_at(step, lr, total_steps, cfg)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, step_lr, cfg)
    return loss, new_p, new_m, new_v


def train_segment(step0, seed, lr, total_steps, tokens_k, params, m, v, cfg: ModelConfig):
    """K optimizer steps under one PJRT call. tokens_k: i32[K, B, S+1]."""
    K = tokens_k.shape[0]

    def body(k, carry):
        params, m, v, loss_sum, _ = carry
        loss, params, m, v = train_step(
            step0 + k, seed, lr, total_steps, tokens_k[k], params, m, v, cfg
        )
        return params, m, v, loss_sum + loss, loss

    params, m, v, loss_sum, last = jax.lax.fori_loop(
        0, K, body, (params, m, v, jnp.float32(0.0), jnp.float32(0.0))
    )
    return loss_sum / K, last, params, m, v


def eval_loss(tokens, params, cfg: ModelConfig):
    """Validation loss. The forward quantizer is deterministic for Quartet
    (QuEST RTN), so a fixed key is exact; SR-forward methods eval with the
    same fixed key for reproducibility."""
    key = jax.random.PRNGKey(0)
    return loss_fn(params, tokens[:, :-1], tokens[:, 1:], cfg, key)


def forward(tokens, params, cfg: ModelConfig):
    """Serving entrypoint: prefill logits."""
    return model_forward(params, tokens, cfg, jax.random.PRNGKey(0))
