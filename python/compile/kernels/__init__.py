"""L1 Pallas kernels for Quartet (compiled under ``interpret=True`` on CPU).

Hardware adaptation (see DESIGN.md §3): the paper's Stage-1 CUDA kernel
(Hadamard-as-GEMM in SMEM + quantize epilogue in registers) becomes a
Pallas kernel whose BlockSpec stages (tile_rows, 32·k) tiles through VMEM,
runs the 32×32 Hadamard matmul on the MXU and the quantize/scale/mask
epilogue on the VPU without returning to HBM; the paper's Stage-2
tcgen05.mma block-scaled GEMM becomes a tiled Pallas matmul whose operands
are MXFP4 grid values (scales folded — bit-identical contraction).
"""

from .hadamard import block_hadamard_pallas
from .quantize import quest_fused_pallas, sr_fused_pallas
from .gemm import mxfp4_matmul_pallas
