"""Pure-jnp oracles for every L1 kernel — the CORE correctness reference.

Each function matches the signature of its Pallas counterpart exactly;
``python/tests/test_kernels.py`` pins them equal. Training artifacts are
lowered through this path by default (identical numerics, cheaper HLO);
the Pallas path is lowered for the `quickstart-pallas` artifact to prove
the kernels compose into the same pipeline.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..formats import MX_GROUP, mxfp4_sr, quest_quantize
from ..hadamard import block_hadamard, randomized_block_hadamard


def block_hadamard_ref(x, g: int = MX_GROUP):
    return block_hadamard(x, g)


def quest_fused_ref(x, g: int = MX_GROUP):
    """Hadamard → QuEST RTN projection → trust mask (Algorithm 1, fwd)."""
    xh = block_hadamard(x, g)
    return quest_quantize(xh, g)


def sr_fused_ref(x, signs, u, g: int = MX_GROUP, prescale: float = 0.75):
    """Ĥ_g sign-flip+Hadamard → absmax E8M0 → SR(3/4·x) (Algorithm 1, bwd)."""
    xh = randomized_block_hadamard(x, signs, g)
    return mxfp4_sr(xh, u, g, prescale)


def mxfp4_matmul_ref(a, b):
    """C = A @ B.T in f32 over MXFP4 grid-valued operands."""
    return a @ b.T
