"""Fused quantization kernels (L1) — the paper's "Stage 1".

Two kernels, mirroring Algorithm 1:

* ``quest_fused_pallas`` — forward path: fixed block Hadamard → QuEST
  RMSE-clipped RTN projection to MXFP4 → clip ("trust") mask. One fused
  pass: values make a single HBM→VMEM→HBM round trip.
* ``sr_fused_pallas`` — backward path: Rademacher sign flip → block
  Hadamard → absmax E8M0 scales → unbiased SR of (3/4)·x to E2M1.

Both consume/produce f32; quantized outputs are exact MXFP4 grid values
(scale folded in). The pure-jnp oracle lives in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import (
    E2M1_MAX,
    E8M0_MAX_EXP,
    E8M0_MIN_EXP,
    MX_GROUP,
    QUEST_ALPHA_E2M1,
)
from ..hadamard import hadamard_matrix

# --------------------------------------------------------------------------
# element-wise helpers shared by the kernel bodies (VPU epilogue ops)
# --------------------------------------------------------------------------


def _round_half_away(x):
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def _e2m1_rtn(x):
    a = jnp.abs(x)
    step = jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))
    q = jnp.minimum(_round_half_away(a / step) * step, E2M1_MAX)
    return jnp.sign(x) * q


def _e2m1_sr(x, u):
    a = jnp.clip(jnp.abs(x), 0.0, E2M1_MAX)
    step = jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))
    lo = jnp.floor(a / step) * step
    step_lo = jnp.where(lo < 2.0, 0.5, jnp.where(lo < 4.0, 1.0, 2.0))
    hi = jnp.minimum(lo + step_lo, E2M1_MAX)
    frac = jnp.where(hi > lo, (a - lo) / (hi - lo), 0.0)
    return jnp.sign(x) * jnp.where(u < frac, hi, lo)


def _e8m0(amax, target=E2M1_MAX):
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, 2.0 ** E8M0_MIN_EXP) / target))
    return jnp.exp2(jnp.clip(exp, E8M0_MIN_EXP, E8M0_MAX_EXP))


# --------------------------------------------------------------------------
# QuEST forward kernel: Hadamard ∘ RMSE-clip ∘ RTN ∘ mask, fused
# --------------------------------------------------------------------------


def _quest_kernel(x_ref, h_ref, q_ref, m_ref, *, g: int):
    x = x_ref[...]
    rows, d = x.shape
    # Stage-1a: Hadamard as a direct (rows·d/g, g) @ (g, g) GEMM (MXU path).
    xg = (x.reshape(rows * (d // g), g) @ h_ref[...])
    # Stage-1b: epilogue in-register — RMSE-optimal clip, then pick the
    # lower-MSE of the two neighbouring E8M0 binades per group (matches
    # formats.quest_quantize bit for bit).
    rms = jnp.sqrt(jnp.mean(xg * xg, axis=-1, keepdims=True) + 1e-20)
    e = jnp.log2(jnp.maximum(QUEST_ALPHA_E2M1 * rms / E2M1_MAX, 2.0 ** E8M0_MIN_EXP))
    s_lo = jnp.exp2(jnp.clip(jnp.floor(e), E8M0_MIN_EXP, E8M0_MAX_EXP))
    s_hi = jnp.exp2(jnp.clip(jnp.ceil(e), E8M0_MIN_EXP, E8M0_MAX_EXP))
    q_lo = _e2m1_rtn(xg / s_lo) * s_lo
    q_hi = _e2m1_rtn(xg / s_hi) * s_hi
    mse_lo = jnp.mean((q_lo - xg) ** 2, axis=-1, keepdims=True)
    mse_hi = jnp.mean((q_hi - xg) ** 2, axis=-1, keepdims=True)
    use_lo = mse_lo <= mse_hi
    q = jnp.where(use_lo, q_lo, q_hi)
    s = jnp.where(use_lo, s_lo, s_hi)
    mask = (jnp.abs(xg) <= s * E2M1_MAX).astype(x.dtype)
    q_ref[...] = q.reshape(rows, d)
    m_ref[...] = mask.reshape(rows, d)


def quest_fused_pallas(x, g: int = MX_GROUP, tile_rows: int = 128):
    """Fused forward-path quantizer. x: [rows, d] f32 → (q, mask)."""
    rows, d = x.shape
    tr = min(tile_rows, rows)
    if rows % tr or d % g:
        raise ValueError(f"shape {x.shape} incompatible with tile {tr}/group {g}")
    hm = jnp.asarray(hadamard_matrix(g))
    return pl.pallas_call(
        functools.partial(_quest_kernel, g=g),
        grid=(rows // tr,),
        in_specs=[
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        interpret=True,
    )(x, hm)


# --------------------------------------------------------------------------
# SR backward kernel: sign-flip ∘ Hadamard ∘ absmax scale ∘ SR(3/4 ·), fused
# --------------------------------------------------------------------------


def _sr_kernel(x_ref, signs_ref, u_ref, h_ref, q_ref, *, g: int, prescale: float):
    x = x_ref[...] * signs_ref[...]  # Rademacher diagonal of Ĥ_g
    rows, d = x.shape
    xg = (x.reshape(rows * (d // g), g) @ h_ref[...])
    s = _e8m0(jnp.max(jnp.abs(xg), axis=-1, keepdims=True))
    u = u_ref[...].reshape(rows * (d // g), g)
    q = _e2m1_sr(prescale * xg / s, u) * s
    q_ref[...] = q.reshape(rows, d)


def sr_fused_pallas(x, signs, u, g: int = MX_GROUP, tile_rows: int = 128,
                    prescale: float = 0.75):
    """Fused backward-path quantizer.

    x: [rows, d], signs: [d] (±1), u: [rows, d] uniform(0,1).
    Output values include the 3/4 shrinkage; the GEMM output is rescaled
    by 16/9 downstream (Algorithm 1 lines 4/6 and 9/11).
    """
    rows, d = x.shape
    tr = min(tile_rows, rows)
    if rows % tr or d % g:
        raise ValueError(f"shape {x.shape} incompatible with tile {tr}/group {g}")
    hm = jnp.asarray(hadamard_matrix(g))
    return pl.pallas_call(
        functools.partial(_sr_kernel, g=g, prescale=prescale),
        grid=(rows // tr,),
        in_specs=[
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, signs.reshape(1, d), u, hm)
