"""Pallas block-Hadamard kernel (L1).

The 32-point transform is expressed as a (rows, 32) @ (32, 32) matmul per
group — the exact MXU-friendly formulation the paper uses on the GPU
(Hadamard as a direct GEMM against a fixed 32x32 matrix in SMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import MX_GROUP
from ..hadamard import hadamard_matrix


def _hadamard_kernel(x_ref, h_ref, o_ref, *, g: int):
    x = x_ref[...]
    rows, d = x.shape
    xg = x.reshape(rows * (d // g), g)
    o_ref[...] = (xg @ h_ref[...]).reshape(rows, d)


def block_hadamard_pallas(x, g: int = MX_GROUP, tile_rows: int = 128):
    """H_g applied per 32-group along the last axis of a 2-D array.

    Grid tiles rows so each VMEM-resident tile is (tile_rows, d); the
    Hadamard matrix rides along in every tile (32x32 f32 = 4 KiB of VMEM).
    """
    rows, d = x.shape
    if d % g:
        raise ValueError(f"last dim {d} % group {g} != 0")
    tr = min(tile_rows, rows)
    if rows % tr:
        raise ValueError(f"rows {rows} % tile {tr} != 0")
    hm = jnp.asarray(hadamard_matrix(g))
    return pl.pallas_call(
        functools.partial(_hadamard_kernel, g=g),
        grid=(rows // tr,),
        in_specs=[
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, hm)
