"""Block-scaled MXFP4 GEMM kernel (L1) — the paper's "Stage 2".

Blackwell's ``tcgen05.mma`` computes ``D = (A·SFA)(B·SFB)`` with one scale
per 32 elements along K. Our operands arrive as exact MXFP4 grid values
with the E8M0 scales already folded (mathematically identical: the scale
is per-K-group, so folding commutes with the contraction). The kernel is
a classic VMEM-tiled matmul: grid (M/tm, N/tn, K/tk) with an f32
accumulator tile revisited across the K loop — the Pallas rendering of
the tensor-core pipeline, with dequantization in the MAC loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...].T


def mxfp4_matmul_pallas(a, b, tile_m: int = 128, tile_n: int = 128,
                        tile_k: int = 128):
    """C = A @ B.T for A:[M,K], B:[N,K] (both MXFP4 grid values), f32 accum.

    B is taken in [N, K] layout — the layout tcgen05.mma block-scaled GEMM
    expects for the second operand (scales along K for both operands).
    """
    m, k = a.shape
    n, kb = b.shape
    if k != kb:
        raise ValueError(f"contraction mismatch {a.shape} vs {b.shape}")
    tm, tn, tk = min(tile_m, m), min(tile_n, n), min(tile_k, k)
    if m % tm or n % tn or k % tk:
        raise ValueError(f"{(m, n, k)} not divisible by tiles {(tm, tn, tk)}")
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, l: (i, l)),
            pl.BlockSpec((tn, tk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
