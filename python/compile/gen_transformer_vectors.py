"""Emit cross-language golden vectors pinning the rust native transformer
forward (`train::transformer::TransformerLm::logits`) against the numpy
float32 twin in `compile/native_transformer.py`, per TrainMethod.

Usage: ``python -m compile.gen_transformer_vectors [out.json]`` (default
writes ``rust/tests/data/transformer_vectors.json``). Regenerate whenever
the transformer architecture or the quantizer numerics change;
``rust/tests/transformer_vectors.rs`` consumes the file.

Weights are a deterministic integer lattice (exactly representable in
f32, identical on both sides without sharing an RNG):

    w[i]     = (((i*37 + salt*101) % 113) - 56) / 64 * scale
    gain[i]  = 1 + (((i + salt) % 7) - 3) / 32

with the salts/scales listed in ``build_model`` — the rust test re-derives
the same tensors from the same formula.
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

from .native_transformer import Block, transformer_logits

VOCAB = 32
D_MODEL = 32
N_HEADS = 2
N_LAYERS = 2
D_FF = 32
SEQ = 8
METHODS = ["f32", "mxfp8", "quartet", "rtn"]


def det_vals(n, salt, scale):
    i = np.arange(n, dtype=np.int64)
    h = (i * 37 + salt * 101) % 113
    return ((h - 56).astype(np.float32) / np.float32(64.0) * np.float32(scale)).astype(
        np.float32
    )


def det_gain(n, salt):
    i = np.arange(n, dtype=np.int64)
    return (
        np.float32(1.0)
        + (((i + salt) % 7) - 3).astype(np.float32) / np.float32(32.0)
    ).astype(np.float32)


def build_model():
    tok_emb = det_vals(VOCAB * D_MODEL, 1, 1.0).reshape(VOCAB, D_MODEL)
    blocks = []
    for b in range(N_LAYERS):
        base = 10 + 16 * b
        blocks.append(
            Block(
                attn_norm=det_gain(D_MODEL, b),
                wq=det_vals(D_MODEL * D_MODEL, base, 0.25).reshape(D_MODEL, D_MODEL),
                wk=det_vals(D_MODEL * D_MODEL, base + 1, 0.25).reshape(D_MODEL, D_MODEL),
                wv=det_vals(D_MODEL * D_MODEL, base + 2, 0.25).reshape(D_MODEL, D_MODEL),
                wo=det_vals(D_MODEL * D_MODEL, base + 3, 0.25).reshape(D_MODEL, D_MODEL),
                mlp_norm=det_gain(D_MODEL, b + 3),
                w_gate=det_vals(D_FF * D_MODEL, base + 4, 0.25).reshape(D_FF, D_MODEL),
                w_up=det_vals(D_FF * D_MODEL, base + 5, 0.25).reshape(D_FF, D_MODEL),
                w_down=det_vals(D_MODEL * D_FF, base + 6, 0.25).reshape(D_MODEL, D_FF),
            )
        )
    final_norm = det_gain(D_MODEL, 11)
    return tok_emb, blocks, final_norm


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
        "transformer_vectors.json")
    tok_emb, blocks, final_norm = build_model()
    tokens = [(7 * i + 3) % VOCAB for i in range(SEQ)]
    cases = []
    for method in METHODS:
        logits = transformer_logits(tok_emb, blocks, final_norm, tokens, N_HEADS, method)
        assert logits.shape == (SEQ, VOCAB)
        assert np.all(np.isfinite(logits)), method
        cases.append({
            "method": method,
            "logits": [float(v) for v in logits.reshape(-1)],
        })
    payload = {
        "config": {
            "vocab": VOCAB,
            "d_model": D_MODEL,
            "n_heads": N_HEADS,
            "n_layers": N_LAYERS,
            "d_ff": D_FF,
            "seq": SEQ,
        },
        "tokens": tokens,
        "cases": cases,
    }
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f)
    print(f"wrote {len(cases)} method cases to {out}")


if __name__ == "__main__":
    main()
