"""Emit cross-language golden vectors pinning the python (L1/L2) and rust
(L3) numeric-format substrates to identical deterministic quantization.

Usage: ``python -m compile.gen_vectors [out.json]`` (default writes
``rust/tests/data/quant_vectors.json``). Regenerate whenever the grid,
scale rule or QuEST alpha changes; `rust prop_quant::golden_vectors_match_python`
consumes the file.
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

from .formats import mxfp4_rtn, quest_quantize


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
        "quant_vectors.json")
    rng = np.random.default_rng(20250710)
    cases = []
    for cols, scale in [(32, 1.0), (64, 0.01), (96, 100.0), (32, 1e-6), (64, 1.0)]:
        x = (rng.standard_normal(cols) * scale).astype(np.float32)
        # exercise exact zeros and an outlier
        x[0] = 0.0
        if cols >= 64:
            x[33] = 8.0 * scale
        q_rtn = np.asarray(mxfp4_rtn(x.reshape(1, -1))).reshape(-1)
        q_quest, mask = quest_quantize(x.reshape(1, -1))
        cases.append({
            "x": [float(v) for v in x],
            "mxfp4_rtn": [float(v) for v in q_rtn],
            "quest_q": [float(v) for v in np.asarray(q_quest).reshape(-1)],
            "quest_mask": [float(v) for v in np.asarray(mask).reshape(-1)],
        })
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"seed": 20250710, "cases": cases}, f)
    print(f"wrote {len(cases)} cases to {out}")


if __name__ == "__main__":
    main()
