"""L2 model: shapes, training dynamics, segment/step equivalence, schedule."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    adamw_update,
    eval_loss,
    forward,
    init_params,
    loss_fn,
    lr_at,
    param_shapes,
    train_segment,
    train_step,
)

CFG = ModelConfig(name="t", d_model=32, n_layers=2, n_heads=2, d_ff=64,
                  vocab=128, seq_len=32, batch=4, method="quartet")
RNG = np.random.default_rng(0)
TOKS = jnp.asarray(RNG.integers(0, 128, (4, 33)), jnp.int32)


def _state(cfg, seed=0):
    p = init_params(cfg, seed)
    z = {k: jnp.zeros_like(v) for k, v in p.items()}
    return p, dict(z), {k: jnp.zeros_like(v) for k, v in p.items()}


def test_param_shapes_match_init():
    p = init_params(CFG)
    shapes = param_shapes(CFG)
    assert set(p) == set(shapes)
    for k in p:
        assert tuple(p[k].shape) == tuple(shapes[k]), k


def test_non_embedding_param_count_formula():
    n = sum(int(np.prod(s)) for k, s in param_shapes(CFG).items() if k != "tok_emb")
    assert n == CFG.non_embedding_params()


def test_config_validation():
    with pytest.raises(ValueError):
        ModelConfig(name="bad", d_model=33, n_layers=1, n_heads=1, d_ff=64,
                    vocab=128, seq_len=32, batch=4)
    with pytest.raises(ValueError):
        ModelConfig(name="bad", d_model=32, n_layers=1, n_heads=5, d_ff=64,
                    vocab=128, seq_len=32, batch=4)


def test_forward_shapes_and_causality():
    p = init_params(CFG)
    toks = TOKS[:, :-1]
    logits = forward(toks, p, CFG)
    assert logits.shape == (4, 32, 128)
    # causality: changing a future token must not affect past logits
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % 128)
    logits2 = forward(toks2, p, CFG)
    np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1], atol=1e-5)


def test_initial_loss_near_log_vocab():
    p, m, v = _state(CFG)
    l = float(eval_loss(TOKS, p, CFG))
    assert abs(l - np.log(128)) < 0.3


@pytest.mark.parametrize("method", ["bf16", "fp8", "quartet"])
def test_loss_decreases(method):
    cfg = dataclasses.replace(CFG, method=method, lr=2e-3, total_steps=30)
    p, m, v = _state(cfg)
    ts = jax.jit(lambda s, t, p, m, v: train_step(
        s, jnp.int32(7), jnp.float32(cfg.lr), jnp.float32(30), t, p, m, v, cfg))
    first = None
    for i in range(12):
        loss, p, m, v = ts(jnp.int32(i), TOKS, p, m, v)
        first = first if first is not None else float(loss)
    assert float(loss) < first - 0.05, (method, first, float(loss))


def test_segment_equals_stepwise():
    """K fori_loop steps must reproduce K individual steps exactly
    (same seeds ⇒ same SR noise ⇒ bitwise-comparable trajectories)."""
    cfg = dataclasses.replace(CFG, method="quartet")
    K = 4
    toks_k = jnp.stack([
        jnp.asarray(np.random.default_rng(i).integers(0, 128, (4, 33)), jnp.int32)
        for i in range(K)
    ])
    lr, total = jnp.float32(1e-3), jnp.float32(100)
    seed = jnp.int32(3)

    p1, m1, v1 = _state(cfg)
    for k in range(K):
        _, p1, m1, v1 = train_step(jnp.int32(k), seed, lr, total, toks_k[k],
                                   p1, m1, v1, cfg)

    p2, m2, v2 = _state(cfg)
    mean_l, last_l, p2, m2, v2 = train_segment(jnp.int32(0), seed, lr, total,
                                               toks_k, p2, m2, v2, cfg)
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=2e-4, atol=1e-6, err_msg=k)


def test_train_step_deterministic_given_seed():
    cfg = CFG
    p, m, v = _state(cfg)
    args = (jnp.int32(0), jnp.int32(9), jnp.float32(1e-3), jnp.float32(100), TOKS)
    l1, p1, *_ = train_step(*args, p, m, v, cfg)
    l2, p2, *_ = train_step(*args, p, m, v, cfg)
    assert float(l1) == float(l2)
    np.testing.assert_array_equal(np.asarray(p1["layers.wq"]),
                                  np.asarray(p2["layers.wq"]))


def test_lr_schedule_warmup_and_cosine():
    total = 100.0
    lrs = [float(lr_at(jnp.int32(s), 1e-3, total, CFG)) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[9]          # warmup rises
    assert abs(max(lrs) - 1e-3) < 1e-4       # peaks at base LR
    assert lrs[99] < 1e-4                    # cosine decays to ~0
    assert all(l > 0 for l in lrs)


def test_grad_clip_applied():
    """With a huge LR-free gradient, update magnitude stays bounded."""
    cfg = dataclasses.replace(CFG, method="bf16", grad_clip=1.0)
    p, m, v = _state(cfg)
    grads = {k: jnp.full_like(x, 100.0) for k, x in p.items()}
    np_, nm, nv = adamw_update(p, grads, m, v, jnp.int32(0), jnp.float32(1.0), cfg)
    gnorm = float(jnp.sqrt(sum(jnp.sum((grads[k] * 0 + 100.0) ** 2) for k in grads)))
    # post-clip first-moment norm ≈ (1-b1)·clip = 0.1
    mnorm = float(jnp.sqrt(sum(jnp.sum(nm[k] ** 2) for k in nm)))
    assert mnorm < 0.11


def test_weight_decay_only_on_linears():
    cfg = dataclasses.replace(CFG, method="bf16", weight_decay=0.5)
    p, m, v = _state(cfg)
    zero_grads = {k: jnp.zeros_like(x) for k, x in p.items()}
    np_, _, _ = adamw_update(p, zero_grads, m, v, jnp.int32(50), jnp.float32(0.1), cfg)
    # linears shrink, norms don't
    assert float(jnp.max(jnp.abs(np_["layers.wq"] - p["layers.wq"]))) > 0
    np.testing.assert_array_equal(np.asarray(np_["final_norm"]),
                                  np.asarray(p["final_norm"]))
