"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes; equality is exact-or-nearly (same op sequence on
the same data — only the tiling differs, which XLA CPU evaluates
deterministically).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_hadamard_pallas,
    mxfp4_matmul_pallas,
    quest_fused_pallas,
    sr_fused_pallas,
)
from compile.kernels import ref

RNG = np.random.default_rng(99)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32) * scale)


ROWS = st.sampled_from([32, 64, 128, 256])
GROUPS = st.sampled_from([1, 2, 4])


@given(rows=ROWS, groups=GROUPS)
@settings(max_examples=12, deadline=None)
def test_hadamard_kernel_matches_ref(rows, groups):
    x = _rand((rows, groups * 32))
    got = block_hadamard_pallas(x, tile_rows=32)
    want = ref.block_hadamard_ref(x)
    np.testing.assert_allclose(got, want, atol=1e-5)


@given(rows=ROWS, groups=GROUPS, scale=st.sampled_from([0.01, 1.0, 100.0]))
@settings(max_examples=12, deadline=None)
def test_quest_kernel_matches_ref(rows, groups, scale):
    x = _rand((rows, groups * 32), scale)
    q1, m1 = quest_fused_pallas(x, tile_rows=32)
    q2, m2 = ref.quest_fused_ref(x)
    np.testing.assert_allclose(q1, q2, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@given(rows=ROWS, groups=GROUPS)
@settings(max_examples=12, deadline=None)
def test_sr_kernel_matches_ref(rows, groups):
    d = groups * 32
    x = _rand((rows, d))
    signs = jnp.asarray(RNG.choice([-1.0, 1.0], d).astype(np.float32))
    u = jnp.asarray(RNG.random((rows, d)).astype(np.float32))
    got = sr_fused_pallas(x, signs, u, tile_rows=32)
    want = ref.sr_fused_ref(x, signs, u)
    np.testing.assert_allclose(got, want, atol=1e-6)


@given(m=st.sampled_from([32, 128]), n=st.sampled_from([32, 64]),
       k=st.sampled_from([32, 128, 256]))
@settings(max_examples=12, deadline=None)
def test_gemm_kernel_matches_ref(m, n, k):
    a, b = _rand((m, k)), _rand((n, k))
    got = mxfp4_matmul_pallas(a, b, tile_m=32, tile_n=32, tile_k=32)
    want = ref.mxfp4_matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_multi_k_tile_accumulation():
    """K-loop accumulation across grid steps (the tcgen05 pipeline analog)."""
    a, b = _rand((64, 512)), _rand((64, 512))
    got = mxfp4_matmul_pallas(a, b, tile_m=32, tile_n=32, tile_k=64)
    np.testing.assert_allclose(got, a @ b.T, rtol=1e-4, atol=1e-4)


def test_kernels_jit_compile():
    """Kernels must lower inside jit (what aot.py relies on)."""
    x = _rand((64, 64))

    @jax.jit
    def f(x):
        q, m = quest_fused_pallas(x)
        return jnp.sum(q) + jnp.sum(m)

    assert np.isfinite(float(f(x)))


def test_sr_kernel_error_masked_by_16_9_identity():
    """Full Algorithm-1 backward identity through the kernels:
    E[(16/9)·SR(¾Ĥg)·SR(¾Ĥw)ᵀ] ≈ g·wᵀ."""
    d = 64
    g2 = _rand((32, d))
    w2 = _rand((16, d))
    signs = jnp.asarray(RNG.choice([-1.0, 1.0], d).astype(np.float32))
    acc = np.zeros((32, 16), np.float64)
    trials = 200
    for i in range(trials):
        r = np.random.default_rng(i)
        ug = jnp.asarray(r.random((32, d)).astype(np.float32))
        uw = jnp.asarray(r.random((16, d)).astype(np.float32))
        gq = sr_fused_pallas(g2, signs, ug, tile_rows=32)
        wq = sr_fused_pallas(w2, signs, uw, tile_rows=16)
        acc += (16.0 / 9.0) * np.asarray(mxfp4_matmul_pallas(gq, wq, tile_m=32, tile_n=16, tile_k=32))
    est = acc / trials
    want = np.asarray(g2 @ w2.T)
    denom = np.abs(want).mean()
    assert np.abs(est - want).mean() / denom < 0.1
