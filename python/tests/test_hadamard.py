"""Hadamard transform invariants used throughout Algorithm 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.hadamard import (
    block_hadamard,
    block_hadamard_inv,
    hadamard_matrix,
    rademacher_signs,
    randomized_block_hadamard,
    randomized_block_hadamard_inv,
)

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("g", [2, 4, 8, 16, 32, 64])
def test_hadamard_orthogonal(g):
    h = hadamard_matrix(g)
    assert np.allclose(h @ h.T, np.eye(g), atol=1e-5)
    assert set(np.round(np.unique(np.abs(h * np.sqrt(g))), 5)) == {1.0}


def test_hadamard_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hadamard_matrix(24)


@given(rows=st.sampled_from([1, 4, 32]), groups=st.sampled_from([1, 2, 5]))
@settings(max_examples=30, deadline=None)
def test_block_hadamard_roundtrip(rows, groups):
    x = jnp.asarray(RNG.standard_normal((rows, groups * 32)).astype(np.float32))
    y = block_hadamard_inv(block_hadamard(x))
    assert np.allclose(y, x, atol=1e-5)


def test_block_hadamard_preserves_norm():
    x = jnp.asarray(RNG.standard_normal((16, 128)).astype(np.float32))
    assert np.isclose(float(jnp.linalg.norm(block_hadamard(x))),
                      float(jnp.linalg.norm(x)), rtol=1e-5)


def test_block_hadamard_preserves_group_inner_products():
    """(H x)·(H w) == x·w per 32-block — why the forward GEMM stays exact."""
    x = jnp.asarray(RNG.standard_normal((8, 64)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((8, 64)).astype(np.float32))
    lhs = jnp.sum(block_hadamard(x) * block_hadamard(w), axis=-1)
    rhs = jnp.sum(x * w, axis=-1)
    assert np.allclose(lhs, rhs, atol=1e-4)


def test_randomized_hadamard_cancels_in_contraction():
    """Ĥ(g,ξ)·Ĥ(w,ξ) == g·w — why the backward GEMMs stay exact pre-quant."""
    key = jax.random.PRNGKey(3)
    signs = rademacher_signs(key, 96)
    g = jnp.asarray(RNG.standard_normal((16, 96)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((20, 96)).astype(np.float32))
    lhs = randomized_block_hadamard(g, signs) @ randomized_block_hadamard(w, signs).T
    assert np.allclose(lhs, g @ w.T, atol=1e-3)


def test_randomized_hadamard_roundtrip():
    key = jax.random.PRNGKey(5)
    signs = rademacher_signs(key, 64)
    x = jnp.asarray(RNG.standard_normal((8, 64)).astype(np.float32))
    y = randomized_block_hadamard_inv(randomized_block_hadamard(x, signs), signs)
    assert np.allclose(y, x, atol=1e-5)


def test_rademacher_signs_are_pm_one():
    s = np.asarray(rademacher_signs(jax.random.PRNGKey(0), 256))
    assert set(np.unique(s)) == {-1.0, 1.0}
    assert abs(s.mean()) < 0.25  # balanced-ish


def test_hadamard_spreads_outliers():
    """A single spike becomes ±1/√32 spread over its group — the outlier
    mitigation that makes MXFP4 grids usable (paper §3)."""
    x = np.zeros((1, 32), np.float32)
    x[0, 5] = 32.0
    y = np.asarray(block_hadamard(jnp.asarray(x)))
    assert np.allclose(np.abs(y), 32.0 / np.sqrt(32), atol=1e-4)
