"""Format substrate tests: grids, scales, rounding — incl. hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.formats as F

RNG = np.random.default_rng(1234)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# E2M1 grid
# ---------------------------------------------------------------------------

FULL_GRID = np.concatenate([-F.E2M1_GRID[::-1], F.E2M1_GRID])


def test_e2m1_rtn_is_nearest_gridpoint():
    x = np.linspace(-8, 8, 4001).astype(np.float32)
    got = np.asarray(F.e2m1_rtn(jnp.asarray(x)))
    # brute force nearest (ties away from zero)
    d = np.abs(x[:, None] - FULL_GRID[None, :])
    best = d.min(axis=1)
    assert np.all(np.abs(np.abs(got) - np.abs(x).clip(max=6)) <= best + 1e-6)
    for g in got:
        assert np.any(np.isclose(np.abs(g), F.E2M1_GRID)), g


def test_e2m1_rtn_ties_away_from_zero():
    # midpoints: 0.25 -> 0.5, 1.25 -> 1.5, 2.5 -> 3, 5.0 -> 6
    x = jnp.asarray([0.25, 1.25, 2.5, 5.0, -0.25, -2.5])
    got = np.asarray(F.e2m1_rtn(x))
    assert np.allclose(got, [0.5, 1.5, 3.0, 6.0, -0.5, -3.0])


def test_e2m1_rtn_clamps():
    assert float(F.e2m1_rtn(jnp.float32(100.0))) == 6.0
    assert float(F.e2m1_rtn(jnp.float32(-100.0))) == -6.0


def test_e2m1_sr_outputs_on_grid():
    x = _rand((1024,), 3.0)
    u = jnp.asarray(RNG.random(1024).astype(np.float32))
    q = np.asarray(F.e2m1_sr(x, u))
    for v in q:
        assert np.any(np.isclose(np.abs(v), F.E2M1_GRID)), v


def test_e2m1_sr_unbiased():
    """E[SR(x)] == clip(x) to statistical precision."""
    x = jnp.full((200_000,), 1.7, jnp.float32)
    u = jnp.asarray(RNG.random(200_000).astype(np.float32))
    q = np.asarray(F.e2m1_sr(x, u))
    assert set(np.round(np.unique(q), 3)).issubset({1.5, 2.0})
    assert abs(q.mean() - 1.7) < 5e-3


@given(st.floats(min_value=-6.0, max_value=6.0, allow_nan=False, width=32))
@settings(max_examples=200, deadline=None)
def test_e2m1_sr_unbiased_pointwise(xval):
    n = 4096
    x = jnp.full((n,), np.float32(xval))
    u = jnp.asarray(np.random.default_rng(abs(hash(xval)) % 2**31).random(n).astype(np.float32))
    q = np.asarray(F.e2m1_sr(x, u))
    lo, hi = q.min(), q.max()
    assert lo <= xval <= hi or np.isclose(lo, hi)
    assert abs(q.mean() - xval) < 0.15  # between-gridpoint gap is <= 2.0


# ---------------------------------------------------------------------------
# E8M0 scales
# ---------------------------------------------------------------------------

def test_e8m0_is_power_of_two_and_covers():
    amax = jnp.asarray(np.abs(RNG.standard_normal(1000)).astype(np.float32) * 10 + 1e-6)
    s = np.asarray(F.e8m0_scale(amax))
    exp = np.log2(s)
    assert np.allclose(exp, np.round(exp))  # powers of two
    assert np.all(amax / s <= F.E2M1_MAX + 1e-6)  # no clipping
    assert np.all(amax / s > F.E2M1_MAX / 2 - 1e-6)  # tight (within one binade)


def test_e8m0_zero_group_safe():
    q = np.asarray(F.mxfp4_rtn(jnp.zeros((4, 32))))
    assert np.all(q == 0) and np.all(np.isfinite(q))
    q = np.asarray(F.mxfp4_sr(jnp.zeros((4, 32)), jnp.full((4, 32), 0.5)))
    assert np.all(q == 0) and np.all(np.isfinite(q))


# ---------------------------------------------------------------------------
# MXFP4 / MXFP8 quant-dequant
# ---------------------------------------------------------------------------

@given(rows=st.sampled_from([1, 2, 8]), groups=st.sampled_from([1, 2, 4]),
       scale=st.floats(min_value=1e-3, max_value=1e3))
@settings(max_examples=50, deadline=None)
def test_mxfp4_rtn_hypothesis(rows, groups, scale):
    x = _rand((rows, groups * 32), scale)
    q = np.asarray(F.mxfp4_rtn(x))
    assert q.shape == x.shape and np.all(np.isfinite(q))
    # every dequant value = grid value * that group's power-of-two scale
    xg = np.asarray(x).reshape(rows, groups, 32)
    qg = q.reshape(rows, groups, 32)
    for r in range(rows):
        for g in range(groups):
            s = np.asarray(F.e8m0_scale(jnp.float32(np.abs(xg[r, g]).max())))
            ratio = qg[r, g] / s
            for v in ratio:
                assert np.any(np.isclose(np.abs(v), F.E2M1_GRID, atol=1e-5)), v


def test_mxfp4_rtn_relative_error_bounded():
    x = _rand((64, 128))
    q = np.asarray(F.mxfp4_rtn(x))
    # grid spacing <= 2 at scale; absmax scaling keeps |err| <= s <= absmax/3
    err = np.abs(q - np.asarray(x))
    gmax = np.abs(np.asarray(x)).reshape(64, 4, 32).max(-1, keepdims=True)
    assert np.all(err.reshape(64, 4, 32) <= gmax / 3 + 1e-6)


def test_mxfp4_sr_unbiased_with_compensation():
    """(4/3)·E[SR(3/4 x)] == x — the Algorithm 1 identity."""
    x = _rand((1, 32), 2.0)
    acc = np.zeros((1, 32), np.float64)
    trials = 3000
    for i in range(trials):
        u = jnp.asarray(np.random.default_rng(i).random((1, 32)).astype(np.float32))
        acc += np.asarray(F.mxfp4_sr(x, u))
    est = (4.0 / 3.0) * acc / trials
    assert np.allclose(est, np.asarray(x), atol=0.05)


def test_mxfp4_sr_never_exceeds_grid_after_prescale():
    x = _rand((16, 64), 100.0)
    u = jnp.asarray(RNG.random((16, 64)).astype(np.float32))
    xg = np.asarray(x).reshape(16, 2, 32)
    q = np.asarray(F.mxfp4_sr(x, u)).reshape(16, 2, 32)
    for r in range(16):
        for g in range(2):
            s = np.asarray(F.e8m0_scale(jnp.float32(np.abs(xg[r, g]).max())))
            assert np.all(np.abs(q[r, g] / s) <= 6.0 + 1e-5)


def test_mxfp8_much_tighter_than_mxfp4():
    x = _rand((256, 128))
    e4 = float(jnp.mean((F.mxfp4_rtn(x) - x) ** 2))
    e8 = float(jnp.mean((F.mxfp8_rtn(x) - x) ** 2))
    assert e8 < e4 / 10  # E4M3 vs E2M1: ~19x on Gaussian data


def test_e4m3_representable_values():
    # spot values exactly representable in E4M3
    for v in [1.0, 1.125, 240.0, 448.0, 0.015625]:
        assert float(F.e4m3(jnp.float32(v))) == v
    assert float(F.e4m3(jnp.float32(1e6))) == F.E4M3_MAX


# ---------------------------------------------------------------------------
# QuEST
# ---------------------------------------------------------------------------

def test_quest_alpha_matches_numeric_fit():
    assert abs(F._fit_quest_alpha(1 << 20) - F.QUEST_ALPHA_E2M1) < 0.15


def test_quest_lower_mse_than_absmax_on_gaussian():
    x = _rand((512, 128))
    q_quest, _ = F.quest_quantize(x)
    q_absmax = F.mxfp4_rtn(x)
    mse_q = float(jnp.mean((q_quest - x) ** 2))
    mse_a = float(jnp.mean((q_absmax - x) ** 2))
    assert mse_q < mse_a  # Table 2: QuEST 1.35e-2 < RTN AbsMax 1.40e-2


def test_quest_mask_marks_clipped():
    x = _rand((32, 32))
    x = x.at[0, 0].set(50.0)  # gross outlier
    q, mask = F.quest_quantize(x)
    assert float(mask[0, 0]) == 0.0
    assert float(jnp.mean(mask)) > 0.9


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_int4_sr_unbiased():
    x = jnp.full((100_000, 32), 0.33, jnp.float32) * jnp.asarray(
        RNG.choice([-1.0, 1.0], (100_000, 32)).astype(np.float32))
    u = jnp.asarray(RNG.random((100_000, 32)).astype(np.float32))
    q = np.asarray(F.int4_sr(x, u))
    assert abs(np.abs(q).mean() - 0.33) < 5e-3


def test_luq_fp4_unbiased():
    x = _rand((1, 32), 1.0)
    acc = np.zeros((1, 32), np.float64)
    trials = 4000
    for i in range(trials):
        u = jnp.asarray(np.random.default_rng(10_000 + i).random((1, 32)).astype(np.float32))
        acc += np.asarray(F.luq_fp4(x, u))
    est = acc / trials
    # unbiased to statistical precision (coarse log grid → bigger tolerance)
    assert np.allclose(est, np.asarray(x), atol=0.08)


def test_jetfire_blocks_independent():
    x = np.ones((64, 64), np.float32)
    x[:32, :32] *= 1000.0  # huge block shouldn't affect others' scales
    q = np.asarray(F.jetfire_fp4(jnp.asarray(x)))
    assert np.allclose(q[32:, 32:], 1.0, atol=0.26)


def test_halo_per_tensor_scale_coarser_than_mxfp4():
    x = _rand((256, 128))
    x = x.at[0, 0].set(500.0)  # single outlier wrecks the whole tensor
    mse_halo = float(jnp.mean((F.halo_fp4(x) - x) ** 2))
    mse_mx = float(jnp.mean((F.mxfp4_rtn(x) - x) ** 2))
    assert mse_halo > mse_mx * 5
