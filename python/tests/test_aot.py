"""AOT pipeline: artifacts lower, manifests are consistent, HLO text parses."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import (
    SIZES,
    base_lr,
    lower_artifact,
    make_config,
    to_hlo_text,
)
from compile.model import param_shapes


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    out = tmp_path_factory.mktemp("art")
    cfg = make_config("n20k", "quartet")
    adir = lower_artifact(cfg, str(out), quiet=True)
    return cfg, adir


def test_sizes_param_counts_ascending():
    counts = [make_config(s, "bf16").non_embedding_params() for s in SIZES]
    assert counts == sorted(counts)
    # labels roughly match the count they advertise
    assert 18_000 < make_config("n20k", "bf16").non_embedding_params() < 23_000
    assert 7e6 < make_config("n8m", "bf16").non_embedding_params() < 9e6


def test_base_lr_monotone_decreasing():
    lrs = [base_lr(make_config(s, "bf16").non_embedding_params()) for s in SIZES]
    assert lrs == sorted(lrs, reverse=True)


def test_manifest_consistent(artifact):
    cfg, adir = artifact
    man = json.load(open(os.path.join(adir, "manifest.json")))
    shapes = param_shapes(cfg)
    assert [p["name"] for p in man["params"]] == list(shapes.keys())
    for p in man["params"]:
        assert tuple(p["shape"]) == tuple(shapes[p["name"]])
    ts = man["entrypoints"]["train_step"]
    # inputs: 4 scalars + tokens + 3*len(params)
    assert len(ts["inputs"]) == 5 + 3 * len(shapes)
    assert ts["inputs"][0]["name"] == "step"
    assert ts["inputs"][4]["name"] == "tokens"
    assert ts["outputs"][0]["name"] == "loss"
    assert man["non_embedding_params"] == cfg.non_embedding_params()


def test_hlo_text_parses_structurally(artifact):
    _, adir = artifact
    for f in ("train_step", "train_segment", "eval_loss", "forward"):
        text = open(os.path.join(adir, f + ".hlo.txt")).read()
        assert "ENTRY" in text and "ROOT" in text, f
        # tuple-rooted (return_tuple=True) so the rust side can decompose
        assert "tuple(" in text or "tuple " in text, f


def test_hlo_text_stable_across_lowerings(artifact):
    """Lowering the same config twice yields identical HLO text — the
    determinism the artifact cache (Makefile stamp) relies on."""
    cfg, adir = artifact
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        adir2 = lower_artifact(cfg, td, entrypoints=("eval_loss",), quiet=True)
        t1 = open(os.path.join(adir, "eval_loss.hlo.txt")).read()
        t2 = open(os.path.join(adir2, "eval_loss.hlo.txt")).read()
    assert t1 == t2


def test_forward_batch_override(tmp_path):
    cfg = make_config("n20k", "quartet", batch=2)
    adir = lower_artifact(cfg, str(tmp_path), entrypoints=("forward",),
                          forward_batch=2, quiet=True)
    man = json.load(open(os.path.join(adir, "manifest.json")))
    assert man["entrypoints"]["forward"]["inputs"][0]["shape"][0] == 2
    assert "train_step" not in man["entrypoints"]


def test_to_hlo_text_simple_fn():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
