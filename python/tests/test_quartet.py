"""Algorithm 1 (quant_linear) semantics: gradients, masks, unbiasedness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.quartet import METHODS, _bwd_gemm, _qlin_fwd, quant_linear

RNG = np.random.default_rng(5)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32) * scale)


X = _rand((64, 32))
W = _rand((32, 32), 0.2)
KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("mname", sorted(METHODS))
def test_every_method_runs_fwd_and_bwd(mname):
    meth = METHODS[mname]

    def loss(x, w):
        return jnp.mean(quant_linear(x, w, KEY, meth) ** 2)

    l = float(loss(X, W))
    dx, dw = jax.grad(loss, argnums=(0, 1))(X, W)
    assert np.isfinite(l)
    assert dx.shape == X.shape and dw.shape == W.shape
    assert bool(jnp.all(jnp.isfinite(dx))) and bool(jnp.all(jnp.isfinite(dw)))


def test_bf16_method_is_exact():
    y = quant_linear(X, W, KEY, METHODS["bf16"])
    np.testing.assert_allclose(y, X @ W.T, rtol=1e-5)

    def loss(x, w):
        return jnp.sum(quant_linear(x, w, KEY, METHODS["bf16"]) * 1.0)

    dx, dw = jax.grad(loss, argnums=(0, 1))(X, W)
    np.testing.assert_allclose(dx, jnp.ones((64, 32)) @ W, rtol=1e-5)
    np.testing.assert_allclose(dw, jnp.ones((64, 32)).T @ X, rtol=1e-5)


def test_quartet_forward_close_to_exact():
    y = quant_linear(X, W, KEY, METHODS["quartet"])
    ref = X @ W.T
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25  # 4-bit fwd: ~11% RMS error per operand, 32-term contraction


def test_quartet_forward_deterministic():
    """QuEST forward is RTN — two keys must give identical y."""
    y1 = quant_linear(X, W, jax.random.PRNGKey(1), METHODS["quartet"])
    y2 = quant_linear(X, W, jax.random.PRNGKey(2), METHODS["quartet"])
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_quartet_backward_stochastic():
    """SR backward: different keys → different gradients (but close)."""

    def grads(key):
        def loss(x, w):
            return jnp.mean(quant_linear(x, w, key, METHODS["quartet"]) ** 2)

        return jax.grad(loss, argnums=(0, 1))(X, W)

    dx1, _ = grads(jax.random.PRNGKey(1))
    dx2, _ = grads(jax.random.PRNGKey(2))
    assert not np.allclose(np.asarray(dx1), np.asarray(dx2))
    rel = float(jnp.linalg.norm(dx1 - dx2) / jnp.linalg.norm(dx1))
    assert rel < 1.0


def test_quartet_gradient_unbiased():
    """E[quartet grad] ≈ masked-STE exact grad; RTN backward is biased.

    This is the paper's Table 2/Figure 2 claim in miniature: the mean
    quartet gradient over SR seeds converges to the clip-masked exact
    gradient, while RTN's stays offset.
    """
    dy = _rand((64, 32))

    def grad_for(mname, seed):
        meth = METHODS[mname]

        def loss(x, w):
            return jnp.sum(quant_linear(x, w, jax.random.PRNGKey(seed), meth) * dy)

        return np.asarray(jax.grad(loss)(X, W))

    # exact masked-STE reference: use the quartet forward residuals
    y, (xq, wq, mx, mw, _) = _qlin_fwd(X, W, KEY, METHODS["quartet"])
    from compile.hadamard import block_hadamard_inv

    ref = np.asarray(block_hadamard_inv((dy @ wq) * mx))

    acc = np.zeros_like(ref, np.float64)
    trials = 120
    for s in range(trials):
        acc += grad_for("quartet", s)
    est = acc / trials
    bias_sr = np.abs(est - ref).mean() / np.abs(ref).mean()
    assert bias_sr < 0.05, bias_sr


def test_quest_trust_mask_blocks_clipped_coordinates():
    """Gradient w.r.t. a grossly-outlying input coordinate must be damped
    by the trust mask (clip-aware STE)."""
    x = X.at[0, :].mul(0.0).at[0, 0].set(1000.0)

    def loss(x):
        return jnp.sum(quant_linear(x, W, KEY, METHODS["quartet"]))

    g = np.asarray(jax.grad(loss)(x))
    gref = np.asarray(jax.grad(lambda x: jnp.sum(x @ W.T))(x))
    # masked rows lose a chunk of their gradient energy
    assert np.abs(g[0]).sum() < np.abs(gref[0]).sum()


def test_bwd_gemm_quartet_sr_unbiased():
    g = _rand((32, 64))
    o = _rand((32, 64))
    want = np.asarray(g @ o.T)
    acc = np.zeros_like(want, np.float64)
    trials = 400
    for s in range(trials):
        acc += np.asarray(_bwd_gemm(g, o, METHODS["quartet"], jax.random.PRNGKey(s)))
    est = acc / trials
    assert np.abs(est - want).mean() / np.abs(want).mean() < 0.05


def test_bwd_gemm_rtn_biased_magnitude():
    """RTN-AbsMax backward has the magnitude bias the PMA metric measures:
    averaged over inputs it shrinks/offsets the product (Table 2)."""
    trials = 60
    tot_ratio = 0.0
    for s in range(trials):
        r = np.random.default_rng(s)
        g = jnp.asarray(r.standard_normal((16, 64)).astype(np.float32))
        o = jnp.asarray(r.standard_normal((16, 64)).astype(np.float32))
        want = np.asarray(g @ o.T)
        got = np.asarray(_bwd_gemm(g, o, METHODS["rtn"], jax.random.PRNGKey(s)))
        num = (got * want).sum()
        den = (want * want).sum()
        tot_ratio += num / den
    # projection coefficient consistently != 1 (here: < 1, shrinkage)
    assert abs(tot_ratio / trials - 1.0) > 1e-3


def test_method_table_complete():
    """The methods table covers everything Table 3 + ablations need."""
    for required in ["quartet", "fp8", "bf16", "luq_int4", "luq_fp4",
                     "jetfire_fp4", "halo_fp4", "lss_int4", "rtn", "sr",
                     "rtn_pma", "quest_fwd", "sr_bwd"]:
        assert required in METHODS, required
